"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``artifacts/dryrun/*.json`` and derives, per (arch x shape x mesh):

  compute term    = HLO flops / chip-peak           (197 TFLOP/s bf16)
  memory term     = HLO bytes accessed / HBM bw     (819 GB/s)
  collective term = wire bytes / link bw            (50 GB/s ICI; /10 DCI)

Wire bytes apply ring-algorithm factors to the parsed per-device result
bytes: all-reduce 2x(n-1)/n, all-gather/reduce-scatter (n-1)/n, all-to-all
(n-1)/n, collective-permute 1x.  n is approximated by the largest mesh axis
(16) — exact group sizes vary per op; the factor range is [0.94, 2].

Also reported: MODEL_FLOPS (6ND / 2ND per token), the useful-flops ratio,
and an attention-traffic-adjusted memory term: the XLA reference path
materializes (bq, S) score tiles in HBM that the Pallas flash kernel keeps
in VMEM on the TPU target — the adjusted term subtracts that traffic to
show the kernel headroom explicitly.
"""
from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
ICI = 50e9
DCI_FACTOR = 10.0
RING_N = 16

FACTORS = {"all-reduce": 2.0 * (RING_N - 1) / RING_N,
           "all-gather": (RING_N - 1) / RING_N,
           "reduce-scatter": (RING_N - 1) / RING_N,
           "all-to-all": (RING_N - 1) / RING_N,
           "collective-permute": 1.0}


def wire_bytes(collectives: dict) -> float:
    total = 0.0
    for kind, fac in FACTORS.items():
        total += collectives.get(kind, 0.0) * fac
    return total


def load_results(art_dir: str = "artifacts/dryrun") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def _score_traffic_bytes(r: dict) -> float:
    """HBM traffic of the attention score/probs tensors on the XLA
    reference path — traffic the Pallas flash kernel keeps in VMEM on the
    TPU target.  Per attention layer and pass the (B_loc, H_loc, Sq, Sk_eff)
    f32 scores are written+read and the probs written+read again (~4
    touches fwd); training adds remat-fwd + bwd (~10 touches total)."""
    import repro.configs as _cfgs
    cfg = _cfgs.get_config(r["arch"])
    from repro.launch.shapes import SHAPES
    cell = SHAPES[r["shape"]]
    if cell.kind == "decode":
        return 0.0     # decode scores are (B,H,1,S) — negligible
    B, S = cell.global_batch, cell.seq_len
    dp = 32 if r["mesh"].startswith("2x") else 16
    dp_over_model = r.get("env", {}).get("dp_over_model", False)
    if dp_over_model:
        dp *= 16
    B_loc = B // dp if B % dp == 0 else B
    touches = 10.0 if cell.kind == "train" else 4.0
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind not in ("attn", "local", "swa", "xattn"):
            continue
        H_loc = cfg.n_heads / 16 if (cfg.n_heads % 16 == 0
                                     and not dp_over_model) else cfg.n_heads
        sk = cfg.n_frontend_tokens if kind == "xattn" else \
            min(S, cfg.window or S) if kind in ("local", "swa") else S
        # blockwise path bounds the resident tile but traffic is still
        # proportional to Sq x Sk_eff
        total += touches * B_loc * H_loc * S * min(sk, S) * 4.0
    return total


def roofline_row(r: dict) -> dict:
    mesh_multi = r["mesh"].startswith("2x")
    link = ICI / (DCI_FACTOR if mesh_multi else 1.0)
    flops = r["cost"]["flops_per_device"]
    byts = r["cost"]["bytes_per_device"]
    wb = wire_bytes(r.get("collectives", {}))
    compute_s = flops / PEAK
    memory_s = byts / HBM
    adj_bytes = max(byts - _score_traffic_bytes(r), 0.0)
    memory_adj_s = adj_bytes / HBM
    coll_s = wb / link
    dominant = max([("compute", compute_s), ("memory", memory_adj_s),
                    ("collective", coll_s)], key=lambda kv: kv[1])[0]
    step_s = max(compute_s, memory_adj_s, coll_s)
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_adj_s": memory_adj_s,
        "collective_s": coll_s, "bottleneck": dominant,
        "step_lower_bound_s": step_s,
        "model_flops_per_device": r.get("model_flops_per_device", 0.0),
        "useful_ratio": r.get("useful_flops_ratio", 0.0),
        # fraction of roofline the *useful* model flops achieve if the step
        # runs at its dominant-term lower bound:
        "roofline_fraction": (r.get("model_flops_per_device", 0.0) / PEAK)
        / step_s if step_s > 0 else 0.0,
        "peak_gib": r["memory"]["peak_bytes_per_device"] / 2 ** 30,
        "fits_hbm": r["memory"]["peak_bytes_per_device"] < 16 * 2 ** 30,
    }


def table(art_dir: str = "artifacts/dryrun", mesh: str = "16x16",
          mode: str = "datacentric") -> list[dict]:
    rows = []
    for r in load_results(art_dir):
        if r.get("status") != "ok":
            continue
        if r["mesh"] != mesh or r.get("sync_mode", "datacentric") != mode:
            continue
        if r.get("remat", "full") != "full":
            continue
        rows.append(roofline_row(r))
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute ms | memory ms (raw/adj) | "
           "collective ms | bottleneck | roofline frac | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for x in rows:
        lines.append(
            f"| {x['arch']} | {x['shape']} | {x['compute_s']*1e3:.2f} | "
            f"{x['memory_s']*1e3:.2f} / {x['memory_adj_s']*1e3:.2f} | "
            f"{x['collective_s']*1e3:.2f} | "
            f"{x['bottleneck']} | {x['roofline_fraction']:.3f} | "
            f"{x['peak_gib']:.2f} |")
    return hdr + "\n".join(lines)


def bench_rows() -> list[tuple[str, str, float]]:
    out = []
    for x in table():
        out.append(("roofline", f"{x['arch']}__{x['shape']}__frac",
                    x["roofline_fraction"]))
    return out


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(render_markdown(table(mesh=mesh)))
