"""Throughput of the unified ParameterDB layer: dc vs bsp (vs ssp/hogwild).

Three measurements through the *same* code path (``repro.pdb``):

  * threaded backend — real threads training the Sec-6 linear-regression
    workload against :class:`repro.pdb.ThreadedParameterDB`; reports wall
    time, DB ops/sec and end-to-end iterations/sec per policy;
  * sharded server backend — the same workload against real shard
    processes (``repro.pdb.server``): socket RPC, client caches, clock
    gossip; ``serverSxW/<policy>`` rows measure distributed throughput;
  * discrete-event simulator — makespan at scale (no GIL artifacts),
    reporting the paper's improvement-% headline through the shared
    policy engine.

Usage:
  PYTHONPATH=src python -m benchmarks.pdb_throughput [--quick]
  PYTHONPATH=src python -m benchmarks.pdb_throughput --backend server
      # distributed axis only (live shard cluster)

Prints ``name,us_per_call,derived`` CSV rows like benchmarks/run.py:
'us_per_call' is wall time per DB op, 'derived' the throughput metric.
``--json`` also writes benchmarks/BENCH_pdb.json (the checked-in perf
trajectory; see benchmarks/artifacts.py).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import threaded as T
from repro.core.simulator import SimConfig, simulate

POLICIES = ("bsp", "dc", "ssp", "hogwild")


def bench_threaded(n_workers: int = 4, n_iters: int = 60,
                   n_features: int = 960, n_examples: int = 2000,
                   repeats: int = 3) -> list[tuple[str, float, float]]:
    """(name, us_per_db_op, iters_per_sec) per policy — identical workload,
    identical pre-drawn data, only the consistency policy differs."""
    X, y = T.make_synthetic_lr(n_examples, n_features, seed=0)
    task = T.LRTask(X, y, n_iters=n_iters, mode="gd")
    ops_total = n_workers * n_iters * (n_workers + 1)
    rows = []
    for policy in POLICIES:
        delta = 2 if policy == "ssp" else 0   # dc measured exact (delta=0)
        walls = []
        for _ in range(repeats):
            stats = T.run_parallel(task, n_workers, policy=policy,
                                   delta=delta)
            walls.append(stats.wall_time)
        wall = min(walls)
        rows.append((f"threaded/{policy}", wall / ops_total * 1e6,
                     n_iters / wall))
    return rows


def bench_server(n_shards: int = 2, n_workers: int = 4, n_iters: int = 20,
                 n_features: int = 960, n_examples: int = 2000,
                 repeats: int = 2, modes: tuple[bool, ...] = (False, True)
                 ) -> list[tuple[str, float, float]]:
    """(name, us_per_db_op, iters_per_sec) per policy against a live
    shard cluster — the distributed-throughput axis.  Op count matches
    the threaded bench (p*(p+1) DB ops per iteration), so us/op is
    directly comparable: the difference is pure RPC + process cost, less
    whatever the client cache absorbs.  ``serverSxW/<policy>`` rows run
    the per-chunk v1 RPC path; ``serverSxW/<policy>_batched`` the
    protocol-v2 batched + pipelined path (end-to-end rows are partly
    gradient compute — see ``bench_server_readset`` for the isolated
    RPC-layer comparison)."""
    from repro.pdb.server import run_distributed_lr

    X, y = T.make_synthetic_lr(n_examples, n_features, seed=0)
    task = T.LRTask(X, y, n_iters=n_iters, mode="gd")
    ops_total = n_workers * n_iters * (n_workers + 1)
    rows = []
    for policy in POLICIES:
        delta = 2 if policy == "ssp" else 0
        for batched in modes:
            walls = []
            for _ in range(repeats):
                res = run_distributed_lr(task, n_workers, n_shards=n_shards,
                                         policy=policy, delta=delta,
                                         record_history=False,
                                         batched=batched)
                walls.append(res.wall_time)
            wall = min(walls)
            suffix = "_batched" if batched else ""
            rows.append((f"server{n_shards}x{n_workers}/{policy}{suffix}",
                         wall / ops_total * 1e6, n_iters / wall))
    return rows


def bench_server_readset(n_shards: int = 2, n_workers: int = 4,
                         n_chunks: int = 8, chunk_size: int = 240,
                         n_iters: int = 150,
                         modes: tuple[bool, ...] = (False, True)
                         ) -> list[tuple[str, float, float]]:
    """The RPC layer in isolation: one client drives the Def-3 iteration
    shape — ``read_all`` of every chunk plus ``write_many`` of its owned
    group — with no gradient compute in the loop, under hogwild (admission
    never blocks).  ``readset_batched`` vs ``readset`` is therefore the
    pure v1-vs-v2 protocol comparison: per-chunk round-trips against
    batched + pipelined frames, write-behind and one-way broadcasts."""
    from repro.pdb.server import ShardCluster

    chunks = [np.zeros(chunk_size, np.float64) for _ in range(n_chunks)]
    owned = [c for c in range(n_chunks) if c % n_workers == 0]
    ops_per_iter = n_chunks + len(owned)
    rows = []
    for batched in modes:
        cluster = ShardCluster(chunks, n_workers, n_shards,
                               policy="hogwild", delta=0, record=False,
                               batched=batched)
        with cluster:
            client = cluster.make_client(0)
            client.read_all(0, 1)        # warm connections + cache
            client.write_many(0, [(j, 1, chunks[j]) for j in owned])
            t0 = time.perf_counter()
            for i in range(2, n_iters + 2):
                client.read_all(0, i)
                client.write_many(0, [(j, i, chunks[j]) for j in owned])
            client.flush()               # settle write-behind inside the clock
            wall = time.perf_counter() - t0
            client.close()
        suffix = "_batched" if batched else ""
        rows.append((f"server{n_shards}x{n_workers}/readset{suffix}",
                     wall / (n_iters * ops_per_iter) * 1e6,
                     n_iters / wall))
    return rows


def bench_simulated(n_workers: int = 32, n_iters: int = 50
                    ) -> list[tuple[str, float, float]]:
    """(name, makespan_ms, simulated_iters_per_sec) per policy at a worker
    count real threads can't reach on one host."""
    rows = []
    for policy in POLICIES:
        cfg = SimConfig(n_workers=n_workers, n_iters=n_iters, policy=policy,
                        delta=2 if policy in ("ssp", "hogwild") else 0,
                        seed=0)
        res = simulate(cfg)
        rows.append((f"simulated{n_workers}/{policy}", res.makespan,
                     n_iters / (res.makespan / 1e3)))
    return rows


def main() -> None:
    from repro.launch.tuning import apply_tuning
    apply_tuning()
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")
    if "--backend" in sys.argv:
        which = sys.argv[sys.argv.index("--backend") + 1]
        if which != "server":
            raise SystemExit(f"unknown --backend {which!r} (only 'server')")
        rows = bench_server(n_iters=10 if quick else 20,
                            repeats=1 if quick else 2)
        rows += bench_server_readset(n_iters=50 if quick else 150)
        for name, us, thru in rows:
            print(f"{name},{us:.2f},{thru:.2f}")
        return
    t_rows = bench_threaded(n_iters=20 if quick else 60,
                            repeats=1 if quick else 3)
    for name, us, thru in t_rows:
        print(f"{name},{us:.2f},{thru:.2f}")
    v_rows = bench_server(n_iters=10 if quick else 20,
                          repeats=1 if quick else 2)
    v_rows += bench_server_readset(n_iters=50 if quick else 150)
    for name, us, thru in v_rows:
        print(f"{name},{us:.2f},{thru:.2f}")
    s_rows = bench_simulated(n_iters=20 if quick else 50)
    for name, ms, thru in s_rows:
        print(f"{name},{ms:.2f},{thru:.2f}")
    if "--json" in sys.argv:
        from . import artifacts
        artifacts.write_bench_json(artifacts.PDB_JSON,
                                   t_rows + v_rows + s_rows)
        print(f"# wrote {artifacts.PDB_JSON}", file=sys.stderr)

    by = {n: d for n, _, d in t_rows + v_rows + s_rows}
    dc, bsp = by["threaded/dc"], by["threaded/bsp"]
    print(f"# threaded dc vs bsp: {(dc - bsp) / bsp * 100:+.1f}% iters/sec",
          file=sys.stderr)
    dc_v, bsp_v = by["server2x4/dc"], by["server2x4/bsp"]
    print(f"# server(2x4) dc vs bsp: {(dc_v - bsp_v) / bsp_v * 100:+.1f}% "
          f"iters/sec", file=sys.stderr)
    rs, rsb = by["server2x4/readset"], by["server2x4/readset_batched"]
    print(f"# server(2x4) RPC layer, batched vs per-op: {rsb / rs:.2f}x "
          f"iters/sec", file=sys.stderr)
    dc_s, bsp_s = by["simulated32/dc"], by["simulated32/bsp"]
    print(f"# simulated(32) dc vs bsp: {(dc_s - bsp_s) / bsp_s * 100:+.1f}% "
          f"iters/sec", file=sys.stderr)


if __name__ == "__main__":
    main()
