"""One benchmark per paper figure (Sec 6, Fig 2a-2f).

Each function reproduces the corresponding experiment through the
discrete-event simulator (makespan model, calibrated constants — see
EXPERIMENTS.md §Paper-repro for the fidelity discussion) and, where cheap
enough, cross-checks with the live threaded runtime.

Workload mapping (the paper's tasks -> simulator compute_mu):
  GD over 5000x960 synthetic      -> ~8 ms/iter/worker
  SGD over the 150k-feature set   -> ~0.5 ms/iter
  mini-batch(100)                 -> ~2.5 ms/iter
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.simulator import (SimConfig, amdahl_speedup, improvement_pct,
                                  serial_makespan, simulate, trimmed_mean)
from repro.core import threaded as T

GD_MU, SGD_MU, MB_MU = 8.0, 0.5, 2.5
RUNS = 10   # paper: 10 runs, trimmed mean (drop 2 fastest / 2 slowest)


def _trimmed_improvement(p: int, mu: float, n_iters: int = 40,
                         **kw) -> float:
    imps = [improvement_pct(dict(n_workers=p, n_iters=n_iters,
                                 compute_mu=mu, seed=s, **kw))
            for s in range(RUNS)]
    return trimmed_mean(imps)


def fig2a_worker_scaling(rows=None):
    """Fig 2a: % improvement vs workers, GD on synthetic data (paper:
    20% -> ~55% over 6..40 workers)."""
    rows = rows or [6, 12, 16, 24, 32, 40]
    out = []
    for p in rows:
        out.append(("fig2a", f"workers={p}",
                    _trimmed_improvement(p, GD_MU)))
    return out


def fig2b_speedup(rows=None):
    """Fig 2b: absolute speedup curves (BSP vs DC vs Amdahl limit)."""
    rows = rows or [6, 12, 16, 24, 32, 40]
    out = []
    for p in rows:
        base = dict(n_workers=p, n_iters=40, compute_mu=GD_MU, seed=0)
        serial = serial_makespan(SimConfig(**base))
        bsp = serial / simulate(SimConfig(policy="bsp", **base)).makespan
        dc = serial / simulate(SimConfig(policy="dc", **base)).makespan
        out.append(("fig2b", f"speedup_bsp_p{p}", bsp))
        out.append(("fig2b", f"speedup_dc_p{p}", dc))
        out.append(("fig2b", f"amdahl_p{p}", amdahl_speedup(p)))
    return out


def fig2c_feature_scaling(rows=None):
    """Fig 2c: improvement vs feature count for 16/24/40 workers.  More
    features -> more compute per iteration -> sync share shrinks (the
    paper's 75% -> 25% decline at 16 workers)."""
    rows = rows or [960, 4000, 16000, 64000]
    out = []
    for p in (16, 24, 40):
        for n_feat in rows:
            # compute time scales linearly with features (residual pass)
            mu = GD_MU * n_feat / 960.0 / 4.0
            out.append(("fig2c", f"p{p}_features={n_feat}",
                        _trimmed_improvement(p, mu, n_iters=20)))
    return out


def fig2d_sgd_iterations(rows=None):
    """Fig 2d: SGD with varying iteration counts at 6 workers (paper:
    65-75% improvement, flat in iteration count)."""
    rows = rows or [50, 100, 200, 400]
    return [("fig2d", f"iters={n}",
             _trimmed_improvement(6, SGD_MU, n_iters=n)) for n in rows]


def fig2e_sgd_workers(rows=None):
    """Fig 2e: SGD improvement vs workers (paper: 70-75% declining to
    40-50%)."""
    rows = rows or [6, 12, 16, 24, 32, 40]
    return [("fig2e", f"workers={p}",
             _trimmed_improvement(p, SGD_MU)) for p in rows]


def fig2f_minibatch(rows=None):
    """Fig 2f: mini-batch(100): decline with workers much less sharp than
    SGD."""
    rows = rows or [6, 12, 16, 24, 32, 40]
    return [("fig2f", f"workers={p}",
             _trimmed_improvement(p, MB_MU)) for p in rows]


def live_threaded_check():
    """Small live-thread confirmation runs (real locks, real GIL): verify
    the *direction* of the effect and sequential correctness on hardware."""
    X, y = T.make_synthetic_lr(400, 96, seed=0)
    task = T.LRTask(X, y, n_iters=20, mode="gd")
    out = []
    for p in (2, 4):
        t_b, t_d = [], []
        for _ in range(3):
            t_b.append(T.run_parallel(task, p, policy="bsp").wall_time)
            t_d.append(T.run_parallel(task, p, policy="dc").wall_time)
        seq = T.run_sequential(task, p)
        par = T.run_parallel(task, p, policy="dc")
        exact = bool(np.array_equal(seq, par.theta))
        out.append(("live", f"p{p}_bsp_ms", float(np.median(t_b) * 1e3)))
        out.append(("live", f"p{p}_dc_ms", float(np.median(t_d) * 1e3)))
        out.append(("live", f"p{p}_bit_identical", float(exact)))
    return out


ALL_FIGS = [fig2a_worker_scaling, fig2b_speedup, fig2c_feature_scaling,
            fig2d_sgd_iterations, fig2e_sgd_workers, fig2f_minibatch,
            live_threaded_check]
