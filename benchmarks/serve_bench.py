"""Serving throughput benchmark: continuous vs static batching.

Runs the request-level engine (repro.serve) on an open-loop Poisson
workload at two arrival rates and reports, per (mode, rate):

  * ``serve/<mode>@<rate>``  — us per generated token (gated by
    benchmarks/regression_gate.py); derived = sustained tokens/sec.
  * ``serve/speedup@<rate>`` — derived = continuous/static tokens/sec
    ratio, the PR headline number (us_per_call 0: ratio rows are not
    wall-clock and must not be gated).
  * ``serve/lat_p50@<rate>`` / ``serve/lat_p99@<rate>`` — continuous-mode
    request latency; derived = milliseconds (us_per_call 0, ungated:
    open-loop latency includes queueing and is rate-, not code-, bound).

Both modes run the same engine, paged cache and model — the measured gap
is purely the drain-the-batch admission barrier (static waits for every
slot to finish before starting the next wave; continuous joins/evicts
mid-decode).  Rates are chosen above the static baseline's sustained
capacity so the comparison is service-limited, not arrival-limited.

A second suite runs long shared-prefix prompts (hot system prompts +
unique suffixes, Zipf-weighted) through three prompt paths:

  * ``serve/nocache@shared``  — whole-prompt prefill at admission (the
    PR-5 engine path; every prompt recomputed, batch stalls per prefill).
  * ``serve/chunked@shared``  — chunked prefill interleaved with decode
    (no prefix reuse; isolates the scheduling change).
  * ``serve/prefix@shared``   — prefix cache + chunked prefill: requests
    adopt the KV pages of their longest cached prefix.

plus derived-only rows (us_per_call 0, ungated): the prefix/nocache
tok/s ratio (``serve/prefix_speedup@shared``), time-to-first-token p50
per path and p99 for prefix (ms), and the prompt-token cache hit rate.

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke --json
"""
from __future__ import annotations

import argparse
import functools

RATES = {"lo": 100.0, "hi": 400.0}    # requests/second
ARCH = "llama3.2-1b"
BATCH = 4
PAGE = 8
PROMPT_LENS = (8, 16, 32)
GEN_LENS = (8, 16, 32, 96)            # wide spread: the static baseline's
CACHE_LEN = 128                       # slots idle at mean/max = 0.4; fits
#                                       prompt<=32 + gen<=96
SHARED_RATE = 200.0                   # service-limited: prefill-bound mix
SHARED_CACHE_LEN = 512                # long-prompt ring (no wrap: 496+8)
SHARED_PREFIX_LEN = 480               # hot prefix; prompts 488/496 <= 512
SHARED_GEN_LENS = (4, 8)              # short gens: prompt work dominates
SHARED_CHUNK = 64                     # prefill chunk for chunked/prefix

# keys the regression gate requires in BENCH_serve.json — a baseline
# missing one was generated before this suite and must be regenerated
REQUIRED_KEYS = (
    "serve/cont@lo", "serve/static@lo",
    "serve/nocache@shared", "serve/chunked@shared", "serve/prefix@shared",
    "serve/prefix_speedup@shared", "serve/hit_rate@shared",
    "serve/ttft_p50_nocache@shared", "serve/ttft_p50_prefix@shared",
)


@functools.lru_cache(maxsize=1)
def _model():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import paramlib
    from repro.models.transformer import model_specs

    cfg = get_smoke_config(ARCH)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0),
                                dtype=cfg.param_dtype)
    return cfg, params


def _run(mode_continuous: bool, rate: float, n_requests: int, seed: int,
         repeats: int = 2):
    """Best-of-``repeats`` run (max sustained tok/s): open-loop makespans
    are sub-second on the smoke config, so a single run is at the mercy
    of host scheduling jitter; best-of is the usual antidote."""
    from repro.serve import ServeConfig, ServeEngine, open_loop_requests

    cfg, params = _model()
    requests = open_loop_requests(n_requests, rate, cfg.vocab_size,
                                  prompt_lens=PROMPT_LENS,
                                  gen_lens=GEN_LENS, seed=seed)
    scfg = ServeConfig(batch_size=BATCH, page_size=PAGE, cache_len=CACHE_LEN,
                       continuous=mode_continuous)
    best = None
    for _ in range(repeats):
        rep = ServeEngine(cfg, params, scfg).run(requests)
        if best is None or rep.tokens_per_sec > best.tokens_per_sec:
            best = rep
    return best


def _run_shared(mode: str, n_requests: int, seed: int, repeats: int = 2):
    """One shared-prefix run; mode in {nocache, chunked, prefix}."""
    from repro.serve import (ServeConfig, ServeEngine,
                             shared_prefix_requests)

    cfg, params = _model()
    requests = shared_prefix_requests(
        n_requests, SHARED_RATE, cfg.vocab_size, n_prefixes=4,
        prefix_len=SHARED_PREFIX_LEN, suffix_lens=(8, 16),
        gen_lens=SHARED_GEN_LENS, zipf_a=1.2, seed=seed)
    scfg = ServeConfig(
        batch_size=BATCH, page_size=PAGE, cache_len=SHARED_CACHE_LEN,
        continuous=True,
        prefill_chunk=0 if mode == "nocache" else SHARED_CHUNK,
        prefix_cache=(mode == "prefix"))
    best = None
    for _ in range(repeats):
        rep = ServeEngine(cfg, params, scfg).run(requests)
        if best is None or rep.tokens_per_sec > best.tokens_per_sec:
            best = rep
    return best


def bench_rows(smoke: bool = False) -> list[tuple[str, float, float]]:
    n_requests = 48 if smoke else 96
    repeats = 2 if smoke else 3
    rows = []
    for tag, rate in RATES.items():
        reports = {}
        for mode, cont in (("cont", True), ("static", False)):
            rep = _run(cont, rate, n_requests, seed=7, repeats=repeats)
            reports[mode] = rep
            us_per_tok = rep.duration * 1e6 / max(rep.total_tokens, 1)
            rows.append((f"serve/{mode}@{tag}", us_per_tok,
                         rep.tokens_per_sec))
        speedup = (reports["cont"].tokens_per_sec /
                   reports["static"].tokens_per_sec)
        rows.append((f"serve/speedup@{tag}", 0.0, speedup))
        rows.append((f"serve/lat_p50@{tag}", 0.0,
                     reports["cont"].latency_p50 * 1e3))
        rows.append((f"serve/lat_p99@{tag}", 0.0,
                     reports["cont"].latency_p99 * 1e3))

    shared = {}
    n_shared = 24 if smoke else 48    # long prompts: keep runtime bounded
    for mode in ("nocache", "chunked", "prefix"):
        rep = _run_shared(mode, n_shared, seed=11, repeats=repeats)
        shared[mode] = rep
        us_per_tok = rep.duration * 1e6 / max(rep.total_tokens, 1)
        rows.append((f"serve/{mode}@shared", us_per_tok,
                     rep.tokens_per_sec))
    rows.append(("serve/prefix_speedup@shared", 0.0,
                 shared["prefix"].tokens_per_sec /
                 shared["nocache"].tokens_per_sec))
    for mode in ("nocache", "chunked", "prefix"):
        rows.append((f"serve/ttft_p50_{mode}@shared", 0.0,
                     shared[mode].ttft_p50 * 1e3))
    rows.append(("serve/ttft_p99_prefix@shared", 0.0,
                 shared["prefix"].ttft_p99 * 1e3))
    rows.append(("serve/hit_rate@shared", 0.0,
                 shared["prefix"].prefix_hit_rate))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests (CI-sized run)")
    ap.add_argument("--json", action="store_true",
                    help="write benchmarks/BENCH_serve.json")
    args = ap.parse_args(argv)

    rows = bench_rows(smoke=args.smoke)
    for name, us, derived in rows:
        if us:
            print(f"{name:22s} {us:10.1f} us/tok   {derived:8.1f} tok/s")
        else:
            print(f"{name:22s} {'':10s}           {derived:8.2f}")
    if args.json:
        from . import artifacts
        artifacts.write_bench_json(artifacts.SERVE_JSON, rows)
        print(f"wrote {artifacts.SERVE_JSON}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
