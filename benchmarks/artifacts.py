"""Versioned benchmark artifacts: the repo's perf trajectory as data.

Benchmarks emit ``BENCH_<topic>.json`` files checked into the repo — a
list of ``{"name", "us_per_call", "derived", "commit"}`` entries — so
every PR carries its own before/after numbers and CI can gate on
regressions (benchmarks/regression_gate.py) instead of asserting wins in
prose.
"""
from __future__ import annotations

import json
import os
import subprocess

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KERNELS_JSON = os.path.join(REPO_ROOT, "benchmarks", "BENCH_kernels.json")
PDB_JSON = os.path.join(REPO_ROOT, "benchmarks", "BENCH_pdb.json")
SERVE_JSON = os.path.join(REPO_ROOT, "benchmarks", "BENCH_serve.json")


def git_commit() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def write_bench_json(path: str, rows: list[tuple[str, float, float]]) -> None:
    """rows: (name, us_per_call, derived) -> schema'd JSON at ``path``."""
    commit = git_commit()
    entries = [{"name": name, "us_per_call": round(float(us), 3),
                "derived": round(float(derived), 4), "commit": commit}
               for name, us, derived in rows]
    with open(path, "w") as f:
        json.dump(entries, f, indent=1)
        f.write("\n")


def load_bench_json(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)
