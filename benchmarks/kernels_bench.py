"""Micro-benchmarks for the serving/staleness hot path kernels.

Covers the three Pallas fast-path targets and their XLA references:

  * ``decode/attn_*``   — fused single-token GQA decode attention
    (kernels/decode_attention.py) vs the einsum reference; derived =
    effective KV-cache read bandwidth in GB/s (decode is memory bound).
  * ``gather/ring_*``   — ParameterDB stale read: per-leaf dynamic-slice
    chain (tree layout) vs one fused row-gather per parameter group
    (packed layout, kernels/ring_gather.py); derived = speedup vs tree.
  * ``moe/grouped_*``   — grouped-expert FFN (kernels/moe_matmul.py) vs
    the one-hot EGCd dispatch einsums; derived = GFLOP/s.

On CPU hosts only the XLA (``ref``) numbers are wall-clock meaningful —
interpret mode is a Python emulator — so Pallas variants are benchmarked
only when a TPU backend is attached.  Usage:

  PYTHONPATH=src python -m benchmarks.run --quick --json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time_us(fn, *args, repeats: int = 5, inner: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)            # compile outside the clock
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best * 1e6


def _impls() -> list[str]:
    impls = ["ref"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    return impls


def bench_decode(quick: bool = False) -> list[tuple[str, float, float]]:
    from repro.kernels import ops as kops
    B, L, H, KV, hd = (4, 512, 8, 2, 64) if quick else (8, 2048, 16, 4, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KV, hd), jnp.float32)
    valid = jnp.ones((L,), bool)
    cache_bytes = 2 * B * L * KV * hd * 4
    rows = []
    for impl in _impls():
        fn = jax.jit(lambda q, k, v, m, _i=impl: kops.attention_decode(
            q, k, v, m, impl=_i))
        us = _time_us(fn, q, k, v, valid)
        rows.append((f"decode/attn_{impl}", us, cache_bytes / us / 1e3))
    return rows


def bench_ring_gather(quick: bool = False) -> list[tuple[str, float, float]]:
    from repro.pdb.jax_backend import init_delayed_state, make_delayed_step
    n_leaves, leaf = (16, (64, 129)) if quick else (48, (128, 257))
    delta = 3
    params = {f"w{i}": jnp.full(leaf, float(i)) for i in range(n_leaves)}

    def grad_fn(p, _):
        return jnp.zeros(()), jax.tree.map(jnp.zeros_like, p)

    def opt_update(g, s, p):
        return p, s

    rows, times = [], {}
    for layout, packed in (("tree", False), ("packed", True)):
        step = make_delayed_step(grad_fn, opt_update, delta, packed=packed)
        state = init_delayed_state(params, lambda p: (), delta, packed=packed)
        read = jax.jit(step.read_stale)
        times[layout] = _time_us(read, state)
    rows.append(("gather/ring_tree", times["tree"], 1.0))
    rows.append(("gather/ring_packed", times["packed"],
                 times["tree"] / max(times["packed"], 1e-9)))
    return rows


def bench_moe(quick: bool = False) -> list[tuple[str, float, float]]:
    from repro.kernels import ops as kops
    G, g, E, C, d, f = (1, 128, 4, 64, 128, 256) if quick \
        else (2, 256, 8, 64, 256, 512)
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    probs = jax.nn.softmax(jax.random.normal(ks[0], (G, g, E)))
    idx = jnp.argmax(probs, -1)
    oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    pos = (jnp.cumsum(oh, axis=1) - oh).astype(jnp.int32)
    keep = oh.astype(bool) & (pos < C)
    slot = jax.nn.one_hot(jnp.where(keep, pos, C), C,
                          dtype=jnp.float32) * keep[..., None]
    dispatch = slot.astype(bool)
    combine = slot * jnp.max(probs, -1)[..., None, None]
    xg = jax.random.normal(ks[1], (G, g, d), jnp.float32)
    wg = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.05
    wu = jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.05
    wd = jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.05
    flops = 2 * G * E * C * d * f * 3          # three expert matmuls
    rows = []
    for impl in _impls():
        fn = jax.jit(lambda *a, _i=impl: kops.moe_grouped_ffn(*a, impl=_i))
        us = _time_us(fn, dispatch, combine, xg, wg, wu, wd)
        rows.append((f"moe/grouped_{impl}", us, flops / us / 1e3))
    return rows


def bench_rows(quick: bool = False) -> list[tuple[str, float, float]]:
    return (bench_decode(quick) + bench_ring_gather(quick)
            + bench_moe(quick))
