"""Tier-2 perf regression gate: re-run the cheap benchmark subset and
fail on >2x slowdown against the checked-in BENCH_*.json trajectory.

Only ``us_per_call`` is compared, only for names present in both the
baseline artifact and the fresh quick run, and only above a noise floor —
figure/simulator rows (whose 'us_per_call' is harness wall time) are not
re-measured here.  Skips cleanly when no baseline exists, so the gate can
land before the first artifacts do.

  PYTHONPATH=src python -m benchmarks.regression_gate
"""
from __future__ import annotations

import os
import sys

SLOWDOWN_LIMIT = 2.0
NOISE_FLOOR_US = 20.0     # don't gate on sub-20us timings (pure jitter)


def compare(baseline: list[dict], fresh: dict[str, float],
            limit: float = SLOWDOWN_LIMIT,
            floor: float = NOISE_FLOOR_US) -> tuple[list[str], list[str]]:
    """Returns (failures, checked) comparing fresh us/call to baseline.

    Malformed baseline entries and benchmarked names absent from the
    baseline are loud failures, not skips or KeyErrors: a silently
    ungated benchmark is how a regression ships."""
    failures, checked = [], []
    base_names = set()
    for entry in baseline:
        if not isinstance(entry, dict) or "name" not in entry \
                or "us_per_call" not in entry:
            failures.append(f"malformed baseline entry {entry!r} "
                            "(needs 'name' and 'us_per_call'); "
                            "regenerate the BENCH json")
            continue
        name, base_us = entry["name"], float(entry["us_per_call"])
        base_names.add(name)
        if name not in fresh or base_us < floor:
            continue
        checked.append(name)
        now = fresh[name]
        if now > limit * base_us:
            failures.append(f"{name}: {now:.1f}us vs baseline "
                            f"{base_us:.1f}us ({now / base_us:.2f}x, "
                            f"commit {entry.get('commit', '?')})")
    for name in sorted(set(fresh) - base_names):
        failures.append(f"{name}: benchmarked but missing from the "
                        "baseline artifact — rerun the bench with --json "
                        "and check the BENCH file in")
    return failures, checked


def main() -> int:
    from . import artifacts

    suites = []
    if os.path.exists(artifacts.KERNELS_JSON):
        from . import kernels_bench
        suites.append(("kernels", artifacts.KERNELS_JSON,
                       lambda: kernels_bench.bench_rows(quick=True)))
    else:
        print(f"# no baseline {artifacts.KERNELS_JSON}; skipping",
              file=sys.stderr)
    if os.path.exists(artifacts.PDB_JSON):
        from . import pdb_throughput
        suites.append(("pdb", artifacts.PDB_JSON,
                       lambda: pdb_throughput.bench_threaded(
                           n_iters=20, repeats=2)
                       + pdb_throughput.bench_server(
                           n_iters=10, repeats=1)
                       + pdb_throughput.bench_server_readset(n_iters=50)))
    else:
        print(f"# no baseline {artifacts.PDB_JSON}; skipping",
              file=sys.stderr)
    if os.path.exists(artifacts.SERVE_JSON):
        from . import serve_bench
        suites.append(("serve", artifacts.SERVE_JSON,
                       lambda: serve_bench.bench_rows(smoke=True),
                       serve_bench.REQUIRED_KEYS))
    else:
        print(f"# no baseline {artifacts.SERVE_JSON}; skipping",
              file=sys.stderr)
    if not suites:
        print("regression gate: no baselines checked in — nothing to do")
        return 0

    all_failures = []
    for topic, path, run, *required in suites:
        baseline = artifacts.load_bench_json(path)
        base_names = {e.get("name") for e in baseline
                      if isinstance(e, dict)}
        missing = [k for k in (required[0] if required else ())
                   if k not in base_names]
        if missing:
            # a stale baseline silently un-gates whole suites: fail loud
            all_failures += [f"{topic}: baseline {path} is missing "
                             f"required key {k!r} — rerun the bench with "
                             "--json and check the BENCH file in"
                             for k in missing]
            print(f"{topic}: baseline missing {len(missing)} required "
                  "key(s); skipping re-measure")
            continue
        fresh = {name: float(us) for name, us, _ in run()}
        failures, checked = compare(baseline, fresh)
        print(f"{topic}: checked {len(checked)} entries, "
              f"{len(failures)} regression(s)")
        all_failures += failures
    for f in all_failures:
        print(f"REGRESSION {f}", file=sys.stderr)
    return 1 if all_failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
