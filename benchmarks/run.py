"""Benchmark harness entry point: one function per paper table/figure,
kernel micro-benchmarks, plus the roofline summary.

stdout is machine-parseable ``name,us_per_call,derived`` CSV only — all
diagnostics go to stderr as ``#`` comments.  For figure benchmarks
'us_per_call' is the benchmark's own wall time and 'derived' the
reproduced metric (improvement % / speedup / roofline fraction); kernel
rows are real per-call timings (see benchmarks/kernels_bench.py).

``--json`` additionally writes the kernel rows to
benchmarks/BENCH_kernels.json — the checked-in perf trajectory gated by
benchmarks/regression_gate.py.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from repro.launch.tuning import apply_tuning
    apply_tuning()

    from . import artifacts, figures, kernels_bench, roofline

    quick = "--quick" in sys.argv
    write_json = "--json" in sys.argv
    print("name,us_per_call,derived")
    figs = figures.ALL_FIGS
    if quick:
        figs = [figures.fig2a_worker_scaling, figures.fig2e_sgd_workers]
    for fig in figs:
        t0 = time.perf_counter()
        rows = fig()
        dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        for group, label, value in rows:
            print(f"{group}/{label},{dt_us:.1f},{value:.4f}")

    kernel_rows = kernels_bench.bench_rows(quick=quick)
    for name, us, derived in kernel_rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    if write_json:
        artifacts.write_bench_json(artifacts.KERNELS_JSON, kernel_rows)
        print(f"# wrote {artifacts.KERNELS_JSON}", file=sys.stderr)

    # roofline fractions from the dry-run artifacts (if present)
    try:
        rows = roofline.bench_rows()
        for group, label, value in rows:
            print(f"{group}/{label},0.0,{value:.4f}")
        if not rows:
            print("# roofline: no artifacts; run repro.launch.dryrun first",
                  file=sys.stderr)
    except Exception as e:  # artifacts missing: benchmarks still usable
        print(f"# roofline skipped: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
