"""Benchmark harness entry point: one function per paper table/figure plus
the roofline summary.  Prints ``name,us_per_call,derived`` CSV rows — for
figure benchmarks 'us_per_call' is the benchmark's own wall time and
'derived' the reproduced metric (improvement % / speedup / roofline
fraction)."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import figures, roofline

    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")
    figs = figures.ALL_FIGS
    if quick:
        figs = [figures.fig2a_worker_scaling, figures.fig2e_sgd_workers]
    for fig in figs:
        t0 = time.perf_counter()
        rows = fig()
        dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        for group, label, value in rows:
            print(f"{group}/{label},{dt_us:.1f},{value:.4f}")

    # roofline fractions from the dry-run artifacts (if present)
    try:
        rows = roofline.bench_rows()
        for group, label, value in rows:
            print(f"{group}/{label},0.0,{value:.4f}")
        if not rows:
            print("roofline/none,0.0,0.0  # run repro.launch.dryrun first",
                  file=sys.stderr)
    except Exception as e:  # artifacts missing: benchmarks still usable
        print(f"# roofline skipped: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
