"""Unit + fault tests for the distributed ParameterDB (repro.pdb.server).

Covers the layers the conformance matrix exercises only end-to-end:

  * the wire protocol (frame round-trips, array packing, hash sharding);
  * per-worker vector clocks and policy cache-admissibility bounds;
  * the value-bounded staleness policy (vap) and its conditional reads;
  * Lamport-clock history merging (synthetic, order-preservation);
  * the WaitTimeout stall diagnostic (threaded backend and shard RPC);
  * retry-with-backoff and the shard kill/restart drill (snapshot restore
    must preserve delta=0 bit-identity through a mid-run shard death).
"""
import math
import socket
import struct
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core import history as H
from repro.core import threaded as T
from repro.pdb import (ThreadedParameterDB, ValueBoundPolicy, VectorClocks,
                       WaitTimeout, make_policy, merge_timed_histories)
from repro.pdb.server import ShardCluster, owned_chunks, run_distributed_lr, \
    shard_of
from repro.pdb.server import protocol as P
from repro.runtime.fault import Backoff, ShardDeathPlan, retry_with_backoff


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------

def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        arr = np.arange(12, dtype=np.float64).reshape(3, 4)
        meta, payload = P.encode_array(arr)
        P.send_msg(a, {"op": "write", "worker": 1, **meta}, payload)
        header, got = P.recv_msg(b)
        assert header["op"] == "write" and header["worker"] == 1
        np.testing.assert_array_equal(P.decode_array(header, got), arr)
        # empty-payload frame
        P.send_msg(b, {"ok": True})
        header, got = P.recv_msg(a)
        assert header == {"ok": True} and got == b""
    finally:
        a.close()
        b.close()


def test_recv_raises_on_peer_close():
    a, b = socket.socketpair()
    a.close()
    with pytest.raises(ConnectionError):
        P.recv_msg(b)
    b.close()


def test_pack_unpack_arrays():
    arrays = {0: np.zeros(3), 2: np.arange(4, dtype=np.float32),
              5: np.ones((2, 2))}
    manifest, payload = P.pack_arrays(arrays)
    out = P.unpack_arrays(manifest, payload)
    assert set(out) == {0, 2, 5}
    for c, v in arrays.items():
        np.testing.assert_array_equal(out[c], v)
        assert out[c].dtype == v.dtype


def test_pack_unpack_mixed_dtypes_empty_and_0d():
    """pack_arrays/unpack_arrays must preserve dtype and shape exactly —
    including 0-d scalars and zero-length arrays — across mixed-dtype
    batches (the write_batch payload of a heterogeneous chunk set)."""
    arrays = {1: np.arange(6, dtype=np.float16).reshape(2, 3),
              3: np.array(2.5, dtype=np.float32),           # 0-d
              4: np.array([], dtype=np.int64),              # empty
              9: np.arange(4, dtype=np.int64)}
    manifest, payload = P.pack_arrays(arrays)
    out = P.unpack_arrays(manifest, payload)
    assert set(out) == set(arrays)
    for c, v in arrays.items():
        assert out[c].dtype == v.dtype and out[c].shape == v.shape
        np.testing.assert_array_equal(out[c], v)


def test_pack_manifest_offsets_are_contiguous_and_exact():
    arrays = {0: np.zeros(5, dtype=np.float64),
              2: np.ones((3, 2), dtype=np.float16),
              7: np.arange(3, dtype=np.int64)}
    manifest, payload = P.pack_arrays(arrays)
    off = 0
    for cid, dtype, shape, o, nbytes in manifest:
        assert o == off               # densely packed, no gaps or overlap
        assert nbytes == np.dtype(dtype).itemsize * int(np.prod(shape))
        off += nbytes
    assert off == len(payload)
    assert [row[0] for row in manifest] == sorted(arrays)


def test_recv_rejects_oversized_frames():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("!I", P.MAX_FRAME + 1))
        with pytest.raises(ConnectionError, match="oversized header"):
            P.recv_msg(b)
    finally:
        a.close()
        b.close()
    a, b = socket.socketpair()
    try:
        hb = b'{"op":"x"}'
        a.sendall(struct.pack("!I", len(hb)) + hb
                  + struct.pack("!I", P.MAX_FRAME + 1))
        with pytest.raises(ConnectionError, match="oversized payload"):
            P.recv_msg(b)
    finally:
        a.close()
        b.close()


def test_shard_hash_partitions_chunks():
    for n_shards in (1, 2, 3, 5):
        seen = []
        for s in range(n_shards):
            owned = owned_chunks(s, 40, n_shards)
            assert all(shard_of(c, n_shards) == s for c in owned)
            seen += owned
        assert sorted(seen) == list(range(40))   # a partition, no overlap
    # hashing scatters: consecutive chunks don't all land on one shard
    assert len({shard_of(c, 2) for c in range(4)}) == 2


# ---------------------------------------------------------------------------
# Protocol v2: request-id matching, one-way broadcasts, pipelining
# ---------------------------------------------------------------------------

def test_recv_matched_drains_out_of_order_acks():
    """Pipelined messages complete in any order relative to each other:
    the receive loop must drain earlier pending ids until the awaited
    response arrives, and treat an id it never issued as a protocol
    violation (triggering reconnect-and-replay, not silent misdelivery)."""
    from repro.pdb.server.client import ClientParameterDB, _Conn
    client = ClientParameterDB(0, [("127.0.0.1", 9)], n_workers=2,
                               n_chunks=2)
    a, b = socket.socketpair()
    try:
        conn = _Conn(sock=a, pending={1, 2})
        client._conns[0] = conn
        P.send_msg(b, {"ok": True, "id": 2, "ts": 5})   # acks, out of order
        P.send_msg(b, {"ok": True, "id": 1, "ts": 6})
        P.send_msg(b, {"ok": True, "id": 3, "ts": 7, "answer": 42})
        resp, rp = client._recv_matched(conn, 3)
        assert resp["answer"] == 42 and rp == b""
        assert conn.pending == set()          # both acks drained
        assert client.lamport >= 7            # every stamp folded
        P.send_msg(b, {"ok": True, "id": 99})
        with pytest.raises(ConnectionResetError, match="protocol error"):
            client._recv_matched(conn, 4)
    finally:
        a.close()
        b.close()


def test_noreply_broadcast_sends_no_frame_and_ping_barriers():
    """A ``noreply`` message gets *no* response frame; because a shard
    serves each connection FIFO, the next synchronous exchange (ping)
    proves every one-way message before it was processed — here the
    frontier broadcasts that admit a BSP write."""
    from repro.pdb.server.shard import ShardServer
    server = ShardServer(("127.0.0.1", 0))
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    sock = None
    try:
        sock = P.connect(server.server_address, timeout=5.0)
        manifest, payload = P.pack_arrays({0: np.zeros(2)})
        P.send_msg(sock, {"op": "init", "config": {
            "shard_id": 0, "n_shards": 1, "n_workers": 2, "n_chunks": 1,
            "policy": "bsp", "delta": 0, "vbound": None, "timeout": 0.2,
            "record": True}, "manifest": manifest}, payload)
        resp, _ = P.recv_msg(sock)
        assert resp["ok"]
        for w in (0, 1):                      # one-way: no response frames
            P.send_msg(sock, {"op": "frontier", "worker": w, "itr": 1,
                              "id": 100 + w, "noreply": True})
        P.send_msg(sock, {"op": "ping", "id": 7})
        resp, _ = P.recv_msg(sock)            # next frame is the ping's —
        assert resp["id"] == 7 and resp["ok"]  # broadcasts were silent
        P.send_msg(sock, {"op": "can", "kind": "w", "worker": 0,
                          "chunk": 0, "itr": 1, "id": 8})
        resp, _ = P.recv_msg(sock)
        assert resp["id"] == 8 and resp["admissible"]   # frontiers landed
    finally:
        if sock is not None:
            sock.close()
        server.shutdown()
        server.server_close()


def test_connect_phase_timeout_surfaces_as_waittimeout(monkeypatch):
    """A hung (unreachable) shard at connection *establishment* must raise
    the standard WaitTimeout diagnostic, not a raw socket error."""
    from repro.pdb.server.client import ClientParameterDB

    def hang(addr, timeout):
        raise TimeoutError("connect timed out")

    monkeypatch.setattr(P, "connect", hang)
    db = ClientParameterDB(0, [("127.0.0.1", 1)], n_workers=1, n_chunks=1,
                           timeout=0.1, backoff=Backoff(max_retries=0))
    with pytest.raises(WaitTimeout) as ei:
        db.read(0, 0, 1)
    msg = str(ei.value)
    assert "timed out" in msg and "shard0" in msg and "rpc" in msg


# ---------------------------------------------------------------------------
# Shard-state regressions: clock gossip on `can`, post-admission stamps
# ---------------------------------------------------------------------------

def test_can_merges_clock_gossip_and_ticks():
    """`can` must merge the request's piggybacked clocks and tick the
    Lamport clock like every other handler — the gossip alone can flip
    the answer (here: a BSP write admitted by the carried frontier)."""
    from repro.pdb.server.shard import ShardConfig, ShardState
    cfg = ShardConfig(shard_id=0, n_shards=1, n_workers=2, n_chunks=1,
                      policy="bsp", timeout=0.2)
    st = ShardState(cfg, {0: np.zeros(2)})
    resp, _ = st.can({"op": "can", "kind": "w", "worker": 0, "chunk": 0,
                      "itr": 1, "ts": 41,
                      "clocks": {"commit": [0, 0], "frontier": [1, 1]}})
    assert resp["admissible"]         # the piggybacked frontier admits it
    assert resp["ts"] > 41            # receipt event ticked past the sender


def test_blocked_read_is_stamped_after_admitting_write():
    """An op that waited for admission must take its Lamport stamp *after*
    the op that admitted it, or the merged global history misorders them
    (the read would sort before the write whose value it returned)."""
    from repro.pdb.server.shard import ShardConfig, ShardState
    cfg = ShardConfig(shard_id=0, n_shards=1, n_workers=2, n_chunks=1,
                      policy="dc", timeout=5.0)
    st = ShardState(cfg, {0: np.zeros(2)})
    for w in (0, 1):                  # iteration-1 reads: admissible
        st.read({"op": "read", "worker": w, "chunk": 0, "itr": 1})
    done = []

    def blocked():                    # needs w[pi0][1]: blocks
        resp, _ = st.read({"op": "read", "worker": 1, "chunk": 0, "itr": 2})
        done.append(resp)

    t = threading.Thread(target=blocked, daemon=True)
    t.start()
    time.sleep(0.1)                   # let the read reach its admission wait
    meta, payload = P.encode_array(np.ones(2))
    st.write({"op": "write", "worker": 0, "chunk": 0, "itr": 1, **meta},
             payload)
    t.join(timeout=5.0)
    assert done and done[0]["ok"]
    stamps = {(op.kind, op.worker, op.itr): ts
              for ts, op in st.telemetry.timed_history()}
    assert stamps[("r", 1, 2)] > stamps[("w", 0, 1)]


# ---------------------------------------------------------------------------
# Vector clocks + cache admissibility
# ---------------------------------------------------------------------------

def test_vector_clocks_merge_is_elementwise_max():
    c = VectorClocks.zero(3)
    c.observe_commit(0, 5)
    c.observe_frontier(2, 2)
    c.merge([1, 4, 0], [0, 3, 1])
    assert c.commit == [5, 4, 0] and c.frontier == [0, 3, 2]
    assert c.min_commit == 0 and c.min_frontier == 0
    c.observe_commit(0, 3)                    # stale observation: no regress
    assert c.commit[0] == 5


def test_bitvector_cache_admissible_exactly_previous_version():
    pol = make_policy("dc", 2, 0, n_chunks=2)
    assert pol.cache_admissible(0, cached_version=1, itr=2)
    assert not pol.cache_admissible(0, cached_version=0, itr=2)   # stale
    assert not pol.cache_admissible(0, cached_version=2, itr=2)   # ahead


def test_delta_cache_admissible_bound_and_hogwild_disabled():
    pol = make_policy("dc-array", 2, 2, n_chunks=2)
    assert pol.cache_admissible(0, cached_version=1, itr=4)    # 4-1-2 <= 1
    assert not pol.cache_admissible(0, cached_version=0, itr=4)
    hog = make_policy("hogwild", 2, n_chunks=2)
    # an infinite bound would freeze cached values forever: disabled
    assert not hog.cache_admissible(0, cached_version=0, itr=99)


def test_bsp_cache_needs_version_and_commit_frontier():
    pol = make_policy("bsp", 2, n_chunks=2)
    assert not pol.cache_admissible(0, cached_version=1, itr=2)
    pol.observe_commit(0, 1)
    pol.observe_commit(1, 1)
    assert pol.cache_admissible(0, cached_version=1, itr=2)
    assert not pol.cache_admissible(0, cached_version=0, itr=2)


def test_value_bound_policy_unit():
    pol = ValueBoundPolicy(2, vbound=0.5, n_chunks=2)
    assert pol.name == "vap"
    assert math.isinf(pol.delta)              # admission never blocks reads
    assert pol.can_read(0, 0, 9) and pol.can_write(0, 0, 9)
    assert not pol.cache_admissible(0, 0, 1)  # validation is server-side
    with pytest.raises(ValueError):
        ValueBoundPolicy(2, vbound=-1.0)


# ---------------------------------------------------------------------------
# Lamport history merge
# ---------------------------------------------------------------------------

def test_merge_timed_histories_orders_and_preserves():
    r, w = H.r, H.w
    part0 = [(1, r(0, 0, 1)), (4, w(0, 0, 1)), (9, r(0, 0, 2))]
    part1 = [(2, r(1, 1, 1)), (3, w(1, 1, 1))]
    merged = merge_timed_histories([part0, part1])
    assert merged == [r(0, 0, 1), r(1, 1, 1), w(1, 1, 1), w(0, 0, 1),
                      r(0, 0, 2)]
    assert H.is_order_preserving_merge(merged, [[op for _, op in part0],
                                                [op for _, op in part1]])


def test_merge_breaks_lamport_ties_by_shard_then_sequence():
    r = H.r
    part0 = [(5, r(0, 0, 1)), (5, r(0, 0, 2))]
    part1 = [(5, r(1, 1, 1))]
    merged = merge_timed_histories([part0, part1])
    assert merged == [r(0, 0, 1), r(0, 0, 2), r(1, 1, 1)]


# ---------------------------------------------------------------------------
# WaitTimeout diagnostics (satellite: *which* op stalled, not just that
# something did)
# ---------------------------------------------------------------------------

def test_threaded_timeout_names_the_stalled_op():
    db = ThreadedParameterDB([np.zeros(1), np.zeros(1)], 2, policy="dc",
                             timeout=0.05)
    with pytest.raises(WaitTimeout) as ei:
        db.read(1, 0, 3)            # inadmissible forever: nobody writes
    e = ei.value
    assert (e.kind, e.worker, e.chunk, e.itr) == ("r", 1, 0, 3)
    msg = str(e)
    assert "timed out" in msg
    assert "r1[pi0][3]" in msg          # the op, in the paper's notation
    assert "BitVectorPolicy" in msg     # which policy state blocked it


def test_shard_stall_carries_diagnostic_to_client():
    """A stalled admission wait on a *shard* must surface client-side as
    the same WaitTimeout diagnostic, naming the op and the shard."""
    init = [np.zeros(2), np.zeros(2)]
    with ShardCluster(init, n_workers=2, n_shards=1, policy="dc",
                      timeout=0.2) as cluster:
        db = cluster.make_client(0)
        with pytest.raises(WaitTimeout) as ei:
            db.read(0, 0, 3)        # needs w[pi0][2]: never happens
        msg = str(ei.value)
        assert "timed out" in msg and "r0[pi0][3]" in msg
        assert "shard0" in msg
        db.close()


# ---------------------------------------------------------------------------
# Backoff + shard death
# ---------------------------------------------------------------------------

def test_backoff_schedule_is_exponential_and_capped():
    b = Backoff(max_retries=5, base_delay=0.1, multiplier=2.0, max_delay=0.5)
    assert [b.delay(i) for i in range(1, 6)] == [0.1, 0.2, 0.4, 0.5, 0.5]


def test_retry_with_backoff_retries_then_succeeds():
    from repro.pdb import Telemetry
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise ConnectionResetError("boom")
        return "ok"

    tele = Telemetry()
    got = retry_with_backoff(flaky, Backoff(max_retries=5, base_delay=0.001),
                             telemetry=tele)
    assert got == "ok" and len(calls) == 3
    assert tele.stats.retried_steps == 2      # surfaces in staleness summary


def test_retry_with_backoff_exhausts_budget():
    def always():
        raise ConnectionResetError("down")

    with pytest.raises(ConnectionResetError):
        retry_with_backoff(always, Backoff(max_retries=2, base_delay=0.001))


def test_shard_death_plan_fires_once():
    class FakeCluster:
        killed, restarted = [], []

        def kill_shard(self, s):
            self.killed.append(s)

        def restart_shard(self, s):
            self.restarted.append(s)

    plan = ShardDeathPlan(kill_at_step=3, shard=1)
    fc = FakeCluster()
    assert not plan.maybe_kill(2, fc)
    assert plan.maybe_kill(3, fc)
    assert not plan.maybe_kill(3, fc)         # fires exactly once
    assert fc.killed == [1] and fc.restarted == [1]


@pytest.mark.slow
def test_shard_kill_restart_preserves_bit_identity():
    """The full drill: kill a shard mid-run, restart it from its snapshot;
    clients must recover via reconnect-with-backoff, retries must surface
    in telemetry, and delta=0 bit-identity must survive."""
    X, y = T.make_synthetic_lr(120, 24, seed=2)
    task = T.LRTask(X, y, n_iters=8, mode="gd")
    expect = T.run_sequential(task, 4)
    plan = ShardDeathPlan(kill_at_step=4, shard=1, restart=True)
    with tempfile.TemporaryDirectory() as snap:
        res = run_distributed_lr(task, 4, n_shards=2, policy="dc", delta=0,
                                 snapshot_dir=snap, death_plan=plan,
                                 backoff=Backoff(max_retries=12))
    assert plan.fired
    assert res.retries > 0
    assert res.staleness["retried_steps"] >= res.retries
    assert np.array_equal(res.theta, expect)
    assert H.is_sequentially_correct(res.history, 4)


# ---------------------------------------------------------------------------
# Value-bounded staleness end-to-end (Dai et al. 2014 style)
# ---------------------------------------------------------------------------

def test_vap_conditional_reads_validate_within_bound():
    """With a huge vbound nearly every re-read is answered not-modified
    (drift within bound -> no payload); with vbound=0 every changed chunk
    must be re-shipped."""
    X, y = T.make_synthetic_lr(120, 24, seed=0)
    task = T.LRTask(X, y, n_iters=6, mode="gd")
    loose = run_distributed_lr(task, 3, n_shards=2, policy="vap",
                               vbound=1e9)
    tight = run_distributed_lr(task, 3, n_shards=2, policy="vap",
                               vbound=0.0)
    assert loose.cache["cache_validated"] > 0
    assert loose.cache["bytes_saved"] > tight.cache["bytes_saved"]
    assert H.is_complete(loose.history, 3, task.n_iters)
    # vbound=0 behaves like an exact re-fetch: values match hogwild-free
    # reads (single write per chunk/iter), so the run still converges
    init_loss = T.loss(task, np.zeros(task.X.shape[1]))
    assert T.loss(task, tight.theta) < init_loss
