"""Training-driver integration: fault injection, resume equivalence, sync
modes, staleness, compression — the scale features at laptop scale."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod
from repro.runtime.fault import (FailureInjector, InjectedFailure,
                                 RetryPolicy, run_with_recovery)


def _run(argv):
    return train_mod.main(argv)


def test_loss_decreases_datacentric(tmp_path):
    r = _run(["--arch", "llama3.2-1b", "--smoke", "--steps", "25",
              "--batch", "4", "--seq", "64", "--lr", "3e-3",
              "--log-every", "100"])
    assert r["final_loss"] < r["first_loss"]


def test_delta_staleness_trains(tmp_path):
    r = _run(["--arch", "llama3.2-1b", "--smoke", "--steps", "25",
              "--batch", "4", "--seq", "64", "--lr", "3e-3",
              "--delta", "2", "--log-every", "100"])
    assert r["final_loss"] < r["first_loss"]


def test_int8_compression_trains(tmp_path):
    r = _run(["--arch", "smollm-360m", "--smoke", "--steps", "20",
              "--batch", "4", "--seq", "64", "--lr", "3e-3",
              "--compression", "int8", "--log-every", "100"])
    assert r["final_loss"] < r["first_loss"]


def test_crash_and_resume_matches_uninterrupted(tmp_path):
    """The restart drill: train 20; vs train-with-crash-at-10 + resume.
    Final losses must match exactly (deterministic data + checkpoint)."""
    base = ["--arch", "llama3.2-1b", "--smoke", "--batch", "2",
            "--seq", "32", "--lr", "1e-3", "--log-every", "100"]
    r_full = _run(base + ["--steps", "20"])

    ck = str(tmp_path / "ck")
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    crash = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + base +
        ["--steps", "20", "--ckpt-dir", ck, "--ckpt-every", "5",
         "--fail-at-step", "12"],
        capture_output=True, text=True, env=env, timeout=600)
    assert crash.returncode == 17, crash.stderr[-1500:]
    assert "CRASH at step 12" in crash.stdout

    r_resumed = _run(base + ["--steps", "20", "--ckpt-dir", ck, "--resume"])
    # Bit-exactness of resume is asserted in
    # tests/test_checkpoint.py::test_resume_bit_exact (single-process).
    # Across processes, XLA-CPU's Eigen thread pool can reorder reduction
    # partial sums under CPU contention, so this end-to-end drill allows a
    # small tolerance.
    assert r_resumed["final_loss"] == pytest.approx(r_full["final_loss"],
                                                    rel=5e-3)


def test_run_with_recovery_skips_nonfinite():
    calls = []

    def step(state, batch):
        calls.append(1)
        return state + 1, {"loss": jnp.asarray(float("nan"))}

    state, metrics, outcome = run_with_recovery(
        step, 0, None, 3, RetryPolicy(skip_nonfinite=True),
        is_finite=lambda m: bool(jnp.isfinite(m["loss"])))
    assert outcome == "skipped"
    assert state == 0                      # poisoned update discarded


def test_run_with_recovery_retries_transient():
    attempts = []

    def step(state, batch):
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return state + 1, {"loss": jnp.asarray(1.0)}

    state, _, outcome = run_with_recovery(
        step, 0, None, 0, RetryPolicy(max_retries=3))
    assert state == 1 and outcome == "retried" and len(attempts) == 3


def test_injected_failure_raises():
    inj = FailureInjector(fail_steps=(5,))
    with pytest.raises(InjectedFailure):
        run_with_recovery(lambda s, b: (s, {}), 0, None, 5,
                          RetryPolicy(), injector=inj)
    # fires once: after restart the same step passes
    state, _, outcome = run_with_recovery(
        lambda s, b: (s + 1, {}), 0, None, 5, RetryPolicy(), injector=inj)
    assert outcome == "ok"


def test_bsp_and_datacentric_same_math(tmp_path):
    """Theorem 2 at the training-loop level: the sync mode changes the
    sharding layout, not the math — identical losses on CPU."""
    base = ["--arch", "olmo-1b", "--smoke", "--steps", "10", "--batch", "2",
            "--seq", "32", "--lr", "1e-3", "--log-every", "100"]
    r_dc = _run(base + ["--mode", "datacentric"])
    r_bsp = _run(base + ["--mode", "bsp"])
    assert r_dc["final_loss"] == pytest.approx(r_bsp["final_loss"], rel=1e-7)
