"""Per-architecture smoke tests: reduced same-family configs run one
forward + one train step on CPU; shapes and finiteness asserted.
(The FULL configs are exercised via the dry-run only.)"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.core.sync_jax import SyncConfig
from repro.launch.steps import make_train_step
from repro.models import paramlib
from repro.models.transformer import forward, lm_loss, model_specs
from repro.optim import OptConfig, make_optimizer

ARCHS = all_arch_ids()


def _batch(cfg, key, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend == "vision":
        batch["media"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(params, batch["tokens"], cfg,
                          media=batch.get("media"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig(lr=1e-3))
    step = jax.jit(make_train_step(cfg, opt, SyncConfig()))
    batch = _batch(cfg, jax.random.PRNGKey(2))
    new_params, opt_state, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    finite = jax.tree.map(lambda x: bool(jnp.isfinite(x).all()), new_params)
    assert all(jax.tree.leaves(finite))
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry exactly the published dimensions."""
    cfg = get_config(arch)
    cfg.validate()
    expected = {
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_moe_archs_flagged():
    assert get_config("mixtral-8x7b").n_experts == 8
    assert get_config("mixtral-8x7b").top_k == 2
    assert get_config("llama4-scout-17b-a16e").n_experts == 16
    assert get_config("llama4-scout-17b-a16e").top_k == 1


def test_long_context_policy():
    """DESIGN.md §5: long_500k runs only for bounded-state archs."""
    runs = {a: get_config(a).runs_long_context for a in ARCHS}
    assert runs["rwkv6-1.6b"] and runs["recurrentgemma-2b"]
    assert runs["mixtral-8x7b"] and runs["gemma3-4b"]
    for a in ("llama3.2-1b", "smollm-360m", "olmo-1b", "musicgen-large",
              "llama4-scout-17b-a16e", "llama-3.2-vision-11b"):
        assert not runs[a], a


def test_olmo_nonparametric_norm():
    cfg = get_config("olmo-1b")
    assert cfg.norm == "layernorm_np"
    from repro.models.layers import norm_specs
    assert norm_specs(cfg) == {}          # truly parameter-free


def test_loss_decreases_quickly_tiny_model():
    """End-to-end sanity: 60 steps on structured synthetic data must cut
    the loss substantially (the copy structure is learnable)."""
    from repro.data import LMBatchSpec, make_lm_batch
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"),
                              dtype=jnp.float32)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig(lr=1e-2))
    step = jax.jit(make_train_step(cfg, opt, SyncConfig()))
    opt_state = opt.init(params)
    spec = LMBatchSpec(batch=4, seq_len=64, vocab_size=cfg.vocab_size, seed=1)
    losses = []
    for t in range(60):
        params, opt_state, m = step(params, opt_state, make_lm_batch(spec, t))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.85 * losses[0], losses[::10]
