"""Serving engine: scheduling semantics and the serve-while-train contract.

Determinism comes from the "steps" clock (arrivals indexed by decode
step) — no wall time anywhere.  The central claims:

  * continuous batching is a pure scheduling change: every request's
    greedy tokens are identical to the static drain-the-batch baseline
    (join/evict does not perturb surviving sequences);
  * under load it strictly wins: fewer decode steps, lower latency;
  * with a trainer publishing into a LiveParamDB mid-serve, every
    serve-side read observes a version within its group's
    ``SyncConfig.delay_for`` bound, and the shared Op history stays
    ``is_sequentially_correct`` — the paper's oracle, applied to
    inference.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.history import is_sequentially_correct
from repro.core.sync_jax import SyncConfig
from repro.models import paramlib
from repro.models.transformer import model_specs
from repro.serve import (LiveParamDB, ServeConfig, ServeEngine,
                         StaticParams, open_loop_requests)

ARCH = "llama3.2-1b"        # non-MoE: decode rows are batch-independent
SCFG = dict(batch_size=3, page_size=8, cache_len=32, clock="steps")


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config(ARCH)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0),
                                dtype=cfg.param_dtype)
    return cfg, params


def _requests(cfg, rate, n=10, seed=3):
    return open_loop_requests(n, rate, cfg.vocab_size, prompt_lens=(8, 16),
                              gen_lens=(2, 4, 8), seed=seed)


class TestContinuousVsStatic:
    def test_token_identical_and_wins_under_load(self, model):
        cfg, params = model
        reqs = _requests(cfg, rate=100.0)    # near-simultaneous arrivals
        reports = {}
        for cont in (True, False):
            scfg = ServeConfig(continuous=cont, **SCFG)
            reports[cont] = ServeEngine(cfg, params, scfg).run(reqs)
        assert reports[True].outputs == reports[False].outputs
        assert reports[True].n_requests == len(reqs)
        for rep in reports.values():
            for r in reqs:
                assert len(rep.outputs[r.rid]) == r.gen_len
        # continuous strictly wins when the batch is contended
        assert reports[True].decode_steps < reports[False].decode_steps
        assert reports[True].latency_p50 < reports[False].latency_p50
        assert reports[True].utilization > reports[False].utilization

    def test_token_identical_with_staggered_arrivals(self, model):
        """Sparse arrivals: sequences join/evict mid-decode at many
        different interleavings; tokens still match the static oracle."""
        cfg, params = model
        reqs = _requests(cfg, rate=0.5, seed=5)
        outs = {}
        for cont in (True, False):
            scfg = ServeConfig(continuous=cont, **SCFG)
            outs[cont] = ServeEngine(cfg, params, scfg).run(reqs).outputs
        assert outs[True] == outs[False]

    def test_raw_param_tree_is_wrapped(self, model):
        cfg, params = model
        eng = ServeEngine(cfg, params, ServeConfig(**SCFG))
        assert isinstance(eng.db, StaticParams)
        assert eng.db.get() is params


class TestServeWhileTrain:
    def test_delay_bounds_on_every_read(self, model):
        """A trainer publishes every 3 decode steps; serve-side reads of
        each delay group must stay within delay_for, non-vacuously (some
        reads actually observe stale versions), and the combined Op
        history must satisfy the Theorem-5 per-partition conditions."""
        cfg, params = model
        sync = SyncConfig(delta=4, group_delays=(("groups", 4),
                                                 ("embed", 1)))
        db = LiveParamDB(params, sync)
        eng = ServeEngine(cfg, db, ServeConfig(**SCFG))
        itr = [0]

        def trainer(step):
            if step % 3 == 0:
                itr[0] += 1
                # a real weight change, so stale reads serve old values
                new = jax.tree.map(lambda x: x * 0.999, params)
                db.publish(new, itr[0])

        rep = eng.run(_requests(cfg, rate=0.5), step_hook=trainer)
        assert rep.n_requests == 10 and itr[0] > 2
        assert len(db.read_log) > 0
        for r in db.read_log:
            assert 0 <= r.staleness <= r.delay
        assert any(r.staleness > 0 for r in db.read_log)
        # both delay groups were exercised
        assert {r.delay for r in db.read_log} == {1, 4}
        assert is_sequentially_correct(db.telemetry.history, db.n_chunks)
        stats = db.telemetry.summary()
        assert stats["stale_reads"] > 0
        assert stats["max_staleness"] <= 4

    def test_publish_out_of_order_rejected(self, model):
        cfg, params = model
        db = LiveParamDB(params, SyncConfig(delta=2))
        db.publish(params, 1)
        with pytest.raises(ValueError, match="out of order"):
            db.publish(params, 3)

    def test_fresh_groups_follow_the_head(self, model):
        """delay 0 groups must re-read every publish (exact RC)."""
        cfg, params = model
        db = LiveParamDB(params, SyncConfig(delta=0))
        for i in range(1, 4):
            new = jax.tree.map(lambda x: x * (1.0 - 0.1 * i), params)
            db.publish(new, i)
            got = db.get()
            leaf = jax.tree_util.tree_leaves(got)[0]
            want = jax.tree_util.tree_leaves(new)[0]
            assert jnp.array_equal(leaf, want)
        assert all(r.staleness == 0 for r in db.read_log)
        assert is_sequentially_correct(db.telemetry.history, db.n_chunks)


def _shared_reqs(cfg, n=10, rate=100.0, seed=3, n_prefixes=3):
    from repro.serve import shared_prefix_requests
    return shared_prefix_requests(n, rate, cfg.vocab_size,
                                  n_prefixes=n_prefixes, prefix_len=16,
                                  suffix_lens=(4, 8), gen_lens=(2, 4, 8),
                                  seed=seed)


class TestChunkedPrefillAndPrefixCache:
    def test_chunked_matches_static_oracle(self, model):
        """Chunked prefill is a pure scheduling change: tokens identical
        to the whole-prompt static baseline on every request."""
        cfg, params = model
        reqs = _shared_reqs(cfg)
        static = ServeEngine(cfg, params, ServeConfig(
            continuous=False, **SCFG)).run(reqs)
        chunked = ServeEngine(cfg, params, ServeConfig(
            continuous=True, prefill_chunk=8, **SCFG)).run(reqs)
        assert chunked.outputs == static.outputs
        assert chunked.prefill_chunks > 0

    def test_prefix_cache_matches_oracle_and_saves_chunks(self, model):
        """Prefix adoption changes *where* K/V come from, never the
        tokens; shared-prefix traffic must hit and skip prefill work."""
        cfg, params = model
        reqs = _shared_reqs(cfg)
        static = ServeEngine(cfg, params, ServeConfig(
            continuous=False, **SCFG)).run(reqs)
        nocache = ServeEngine(cfg, params, ServeConfig(
            continuous=True, prefill_chunk=8, **SCFG)).run(reqs)
        cached = ServeEngine(cfg, params, ServeConfig(
            continuous=True, prefix_cache=True, **SCFG)).run(reqs)
        assert cached.outputs == static.outputs
        assert cached.prefix_hit_rate > 0.3
        assert cached.prefill_chunks < nocache.prefill_chunks
        assert cached.ttft_p50 <= nocache.ttft_p50

    def test_prefix_cache_staggered_arrivals(self, model):
        """Sparse arrivals: adoption, COW wraps and trie churn interleave
        with decode at many offsets; tokens still match the oracle."""
        cfg, params = model
        reqs = _shared_reqs(cfg, rate=0.5, seed=5)
        static = ServeEngine(cfg, params, ServeConfig(
            continuous=False, **SCFG)).run(reqs)
        cached = ServeEngine(cfg, params, ServeConfig(
            continuous=True, prefix_cache=True, **SCFG)).run(reqs)
        assert cached.outputs == static.outputs

    def test_eviction_under_pressure_stays_correct(self, model):
        """Minimal pool headroom + more hot prefixes than it can hold:
        the trie must evict (not crash) and tokens must stay exact."""
        cfg, params = model
        reqs = _shared_reqs(cfg, n=12, n_prefixes=6, seed=9)
        static = ServeEngine(cfg, params, ServeConfig(
            continuous=False, **SCFG)).run(reqs)
        cached = ServeEngine(cfg, params, ServeConfig(
            continuous=True, prefix_cache=True, prefix_seqs=1,
            **SCFG)).run(reqs)
        assert cached.outputs == static.outputs

    def test_report_fields(self, model):
        cfg, params = model
        rep = ServeEngine(cfg, params, ServeConfig(
            continuous=True, prefix_cache=True, **SCFG)).run(
                _shared_reqs(cfg, n=6))
        assert rep.ttft_p50 >= 0 and rep.ttft_p99 >= rep.ttft_p50
        assert rep.prefill_chunks >= 0
        assert 0.0 <= rep.prefix_hit_rate <= 1.0
        for f in [rep.outputs[r] for r in rep.outputs]:
            assert len(f) > 0

    def test_config_validation(self):
        with pytest.raises(ValueError, match="continuous"):
            ServeConfig(continuous=False, prefill_chunk=8, **SCFG)
        with pytest.raises(ValueError, match="top_p"):
            ServeConfig(top_p=0.0, **SCFG)
        # prefix_cache implies a page-sized prefill chunk
        scfg = ServeConfig(prefix_cache=True, **SCFG)
        assert scfg.prefill_chunk == SCFG["page_size"]


class TestSampling:
    def test_deterministic_across_schedules(self, model):
        """Sampling is keyed by (request, token index) only: continuous
        + prefix-cached and static schedules draw identical tokens."""
        cfg, params = model
        reqs = _shared_reqs(cfg)
        kw = dict(temperature=0.8, top_p=0.9, sample_seed=7)
        a = ServeEngine(cfg, params, ServeConfig(
            continuous=True, prefix_cache=True, **kw, **SCFG)).run(reqs)
        b = ServeEngine(cfg, params, ServeConfig(
            continuous=False, **kw, **SCFG)).run(reqs)
        greedy = ServeEngine(cfg, params, ServeConfig(
            continuous=False, **SCFG)).run(reqs)
        assert a.outputs == b.outputs
        assert a.outputs != greedy.outputs       # it actually sampled
        c = ServeEngine(cfg, params, ServeConfig(
            continuous=False, temperature=0.8, top_p=0.9, sample_seed=8,
            **SCFG)).run(reqs)
        assert c.outputs != b.outputs            # seed matters
