"""Optimizers: AdamW math, clipping, int8 gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptConfig, clip_by_global_norm, compress_grads,
                         global_norm, init_residual, make_optimizer)
from repro.optim.optimizers import dequantize_int8, quantize_int8


def test_adamw_matches_manual():
    cfg = OptConfig(name="adamw", lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                    weight_decay=0.01, grad_clip=0)
    opt = make_optimizer(cfg)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    state = opt.init(p)
    new_p, state = opt.update(g, state, p)

    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.asarray(p["w"]) - 0.1 * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.01 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)


def test_sgd_basic():
    opt = make_optimizer(OptConfig(name="sgd", lr=0.5, grad_clip=0))
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.full(3, 2.0)}
    new_p, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.zeros(3))


def test_momentum_accumulates():
    opt = make_optimizer(OptConfig(name="momentum", lr=1.0, momentum=0.5,
                                   grad_clip=0))
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    s = opt.init(p)
    p, s = opt.update(g, s, p)       # mom=1, w=-1
    p, s = opt.update(g, s, p)       # mom=1.5, w=-2.5
    np.testing.assert_allclose(np.asarray(p["w"]), [-2.5])


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(g)) == pytest.approx(5.0)
    clipped = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the limit: unchanged
    small = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(small["a"]), [3.0])


class TestInt8Compression:
    def test_quantize_roundtrip_bounded_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x).max()
        assert float(err) <= float(s) * 0.51

    def test_error_feedback_is_unbiased_over_time(self):
        """With a constant gradient, the error-feedback residual makes the
        accumulated compressed signal converge to the true total."""
        g = {"w": jnp.full((64,), 0.01303)}
        res = init_residual(g)
        acc = jnp.zeros((64,))
        for t in range(50):
            deq, res = compress_grads(g, res)
            acc = acc + deq["w"]
        total_true = 0.01303 * 50
        np.testing.assert_allclose(np.asarray(acc), total_true, rtol=2e-2)

    def test_compressed_sgd_converges(self):
        key = jax.random.PRNGKey(1)
        A = jax.random.normal(key, (32, 8)) / np.sqrt(8)
        w_true = jax.random.normal(jax.random.PRNGKey(2), (8,))
        y = A @ w_true

        def grad_fn(p):
            r = A @ p["w"] - y
            return {"w": A.T @ r / 32}

        opt = make_optimizer(OptConfig(name="sgd", lr=0.5, grad_clip=0,
                                       compression="int8"))
        p = {"w": jnp.zeros(8)}
        s = opt.init(p)
        for _ in range(300):
            p, s = opt.update(grad_fn(p), s, p)
        final = float(jnp.mean((A @ p["w"] - y) ** 2))
        assert final < 1e-3
