"""Policy x backend conformance matrix for the unified ParameterDB.

Every consistency policy must behave identically through every execution
backend:

  * at delta=0, the sequentially-correct policies (bsp, dc, dc-array) must
    produce final parameters **bit-identical** to single-threaded sequential
    execution, through both the in-process replay backend and the real
    threaded backend;
  * every recorded history (any backend) must be complete and satisfy
    ``history.is_sequentially_correct`` — the single semantic oracle;
  * the SSP policy must respect its clock bound (slack) under the
    ``random_schedule`` property fuzzer and on real threads, while *not*
    being required to be sequentially correct;
  * the JAX ring-buffer backend must agree with ``sequential_result``-style
    ground truth at delta=0 and emit the same kind of Op history;
  * the multi-process sharded backend (``repro.pdb.server``: real shard
    processes + socket RPC + client caches + clock gossip) must meet the
    same bar — delta=0 bit-identity, merged-global-history oracle, SSP
    clock bound — plus distributed-only invariants: the merged history is
    an order-preserving merge of the per-shard histories, and cache hits
    never change results.
"""
import numpy as np
import pytest

from repro.core import history as H
from repro.core import threaded as T
from repro.pdb import (InProcessParameterDB, InadmissibleOp, SSPPolicy,
                       ThreadedParameterDB, make_policy, random_schedule,
                       run_interleaved, ssp_clock_bound_violations)
from repro.pdb.server import ShardCluster, run_distributed_lr

SEQ_POLICIES = ["bsp", "dc", "dc-array"]   # sequentially correct at delta=0
ALL_POLICIES = SEQ_POLICIES + ["ssp", "hogwild"]


@pytest.fixture(scope="module")
def data():
    return T.make_synthetic_lr(120, 24, seed=0)


def _task(data, **kw):
    X, y = data
    kw.setdefault("n_iters", 6)
    return T.LRTask(X, y, mode="gd", **kw)


def _inprocess_theta(task, n_workers, policy, delta=0, seed=0):
    slices = T.chunk_slices(task.X.shape[1], n_workers)
    schedule = task.sample_schedule()
    init = [np.zeros(sl.stop - sl.start) for sl in slices]
    db = InProcessParameterDB(
        init, n_workers,
        policy=make_policy(policy, n_workers, delta, n_chunks=n_workers),
        record=True)

    def update(worker, snap, itr):
        return T.chunk_update(task, snap, slices[worker], itr, schedule)

    theta = run_interleaved(db, task.n_iters, update, seed=seed)
    return theta, db


# ---------------------------------------------------------------------------
# delta=0 bit-identity + history oracle, for every (policy, backend) pair
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", SEQ_POLICIES)
@pytest.mark.parametrize("workers", [2, 4])
def test_inprocess_delta0_bit_identical(data, policy, workers):
    task = _task(data)
    seq = T.run_sequential(task, workers)
    for seed in range(3):           # three different interleavings
        theta, db = _inprocess_theta(task, workers, policy, seed=seed)
        assert np.array_equal(theta, seq)
        assert H.is_complete(db.history, workers, task.n_iters)
        assert H.is_sequentially_correct(db.history, workers)


@pytest.mark.parametrize("policy", SEQ_POLICIES)
@pytest.mark.parametrize("workers", [2, 4])
def test_threaded_delta0_bit_identical(data, policy, workers):
    task = _task(data)
    seq = T.run_sequential(task, workers)
    stats = T.run_parallel(task, workers, policy=policy, record_history=True)
    assert np.array_equal(stats.theta, seq)
    assert H.is_complete(stats.history, workers, task.n_iters)
    assert H.is_sequentially_correct(stats.history, workers)
    # exact policies never serve a stale or read-ahead value
    assert stats.staleness["max_staleness"] == 0
    assert stats.staleness["stale_reads"] == 0
    assert stats.staleness["ahead_reads"] == 0


@pytest.mark.parametrize("backend", ["inproc", "threaded"])
def test_delta_relaxed_still_converges(data, backend):
    task = _task(data, n_iters=25, lr=0.3)
    if backend == "threaded":
        theta = T.run_parallel(task, 4, policy="dc", delta=2).theta
    else:
        theta, _ = _inprocess_theta(task, 4, "dc", delta=2, seed=1)
    init_loss = T.loss(task, np.zeros(task.X.shape[1]))
    assert T.loss(task, theta) < 0.9 * init_loss


# ---------------------------------------------------------------------------
# The same telemetry flows through every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_op_counts_uniform_across_backends(data, policy):
    task = _task(data, n_iters=4)
    p = 3
    delta = 1 if policy in ("dc", "dc-array", "ssp") else 0
    _, db = _inprocess_theta(task, p, policy, delta=delta, seed=0)
    stats = T.run_parallel(task, p, policy=policy, delta=delta,
                           record_history=True)
    want_reads, want_writes = p * p * task.n_iters, p * task.n_iters
    for s in (db.telemetry.summary(), stats.staleness):
        assert s["reads"] == want_reads
        assert s["writes"] == want_writes


# ---------------------------------------------------------------------------
# SSP: clock bound respected, under the fuzzer and on real threads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slack", [0, 1, 3])
@pytest.mark.parametrize("p,n", [(2, 4), (4, 3)])
def test_ssp_clock_bound_random_schedule(slack, p, n):
    for seed in range(8):
        h = random_schedule("ssp", p, n, seed=seed, delta=slack)
        assert len(h) == p * n * (p + 1)       # total progress
        assert ssp_clock_bound_violations(h, p, slack) == []


def test_ssp_random_schedule_can_exceed_smaller_bound():
    """The fuzzer actually exercises the slack: with slack=3 some schedule
    violates the slack=1 bound (otherwise the bound test is vacuous)."""
    found = False
    for seed in range(20):
        h = random_schedule("ssp", 3, 4, seed=seed, delta=3)
        if ssp_clock_bound_violations(h, 3, 1):
            found = True
            break
    assert found


def test_ssp_threaded_respects_bound(data):
    task = _task(data, n_iters=8)
    stats = T.run_parallel(task, 4, policy="ssp", delta=2,
                           record_history=True)
    assert H.is_complete(stats.history, 4, 8)
    assert ssp_clock_bound_violations(stats.history, 4, 2) == []


def test_ssp_policy_admission_unit():
    s = SSPPolicy(2, slack=1)
    assert s.can_read(0, 0, 1) and s.can_read(0, 0, 2)   # within slack
    assert not s.can_read(0, 0, 3)                       # min clock 0 < 3-1-1
    assert s.can_write(0, 0, 99)                         # writes never gated
    s.did_write(1, 1, 1)
    assert not s.can_read(0, 0, 3)                       # worker 0 still at 0
    s.did_write(0, 0, 1)
    assert s.can_read(0, 0, 3)
    with pytest.raises(ValueError):
        SSPPolicy(2, slack=-1)


# ---------------------------------------------------------------------------
# In-process backend: inadmissible ops raise instead of blocking
# ---------------------------------------------------------------------------

def test_inprocess_raises_on_inadmissible():
    db = InProcessParameterDB([np.zeros(2), np.zeros(2)], 2, policy="dc")
    with pytest.raises(InadmissibleOp):
        db.read(0, 0, 2)            # nothing written yet: version 0 != 1
    db.read(0, 0, 1)
    with pytest.raises(InadmissibleOp):
        db.write(0, 0, 1, np.ones(2))   # worker 1 hasn't read chunk 0


def test_threaded_db_timeout_surfaces_deadlock():
    db = ThreadedParameterDB([np.zeros(1)], 1, policy="dc", timeout=0.05)
    with pytest.raises(RuntimeError, match="timed out"):
        db.read(0, 0, 5)            # never admissible: nobody writes


# ---------------------------------------------------------------------------
# JAX ring-buffer backend through the unified engine
# ---------------------------------------------------------------------------

def _toy_engine(delta, group_delays=(), record=True):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core.sync_jax import SyncConfig
    from repro.optim import OptConfig, make_optimizer
    from repro.pdb import make_engine

    dim = 6
    A = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (24, 2 * dim)))
    ytrue = A @ np.ones(2 * dim)
    batch = {"A": jnp.asarray(A), "y": jnp.asarray(ytrue)}
    params = {"a": jnp.zeros((dim,)), "b": jnp.zeros((dim,))}

    def grad_fn(p, b):
        def loss_fn(pp):
            r = b["A"] @ jnp.concatenate([pp["a"], pp["b"]]) - b["y"]
            return 0.5 * jnp.mean(r * r)
        return jax.value_and_grad(loss_fn)(p)

    opt = make_optimizer(OptConfig(name="sgd", lr=0.05, grad_clip=0))
    sync = SyncConfig(delta=delta, group_delays=group_delays)
    eng = make_engine(params, grad_fn, opt, sync, record_history=record)
    return eng, batch


def test_jax_engine_delta0_matches_sequential_and_history():
    eng, batch = _toy_engine(delta=0)
    state = eng.init_state()
    n = 8
    for _ in range(n):
        state, m = eng.step(state, batch)
    # ground truth: plain full-batch GD on the same problem
    w = np.zeros(12)
    A = np.asarray(batch["A"]); y = np.asarray(batch["y"])
    for _ in range(n):
        w = w - 0.05 * (A.T @ (A @ w - y)) / A.shape[0]
    got = np.concatenate([np.asarray(state["params"]["a"]),
                          np.asarray(state["params"]["b"])])
    np.testing.assert_allclose(got, w, rtol=1e-6, atol=1e-7)
    # same Op-history oracle as the host backends (2 groups = 2 chunks)
    assert H.is_sequentially_correct(eng.history, 2)
    assert len(eng.history) == n * (2 + 2)
    assert eng.telemetry.summary()["max_staleness"] == 0


def test_jax_engine_group_delays_telemetry():
    eng, batch = _toy_engine(delta=2, group_delays=(("a", 0),))
    state = eng.init_state()
    for _ in range(6):
        state, m = eng.step(state, batch)
    s = eng.telemetry.summary()
    assert eng.group_delays == (0, 2)       # leaf 'a' fresh, 'b' stale
    assert s["max_staleness"] == 2
    assert s["stale_reads"] > 0
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# The multi-process sharded backend (repro.pdb.server)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batched", [False, True], ids=["per-op", "batched"])
@pytest.mark.parametrize("policy", SEQ_POLICIES)
def test_server_delta0_bit_identical(data, policy, batched):
    """Real shard processes, socket RPC, client caches — and still
    bit-identical to single-threaded sequential execution at delta=0,
    on both the per-chunk v1 path and the batched/pipelined v2 path."""
    task = _task(data)
    workers = 4
    seq = T.run_sequential(task, workers)
    res = run_distributed_lr(task, workers, n_shards=2, policy=policy,
                             delta=0, batched=batched)
    assert np.array_equal(res.theta, seq)
    assert H.is_complete(res.history, workers, task.n_iters)
    assert H.is_sequentially_correct(res.history, workers)
    assert res.staleness["max_staleness"] == 0
    assert res.staleness["stale_reads"] == 0


@pytest.mark.parametrize("batched", [False, True], ids=["per-op", "batched"])
def test_server_delta_relaxed_cache_hits(data, batched):
    """delta>0 must respect the staleness bound, and the client cache must
    actually serve reads (admissible cached versions skip the payload) —
    as piggybacked ``notify`` batch entries on the v2 path."""
    task = _task(data, n_iters=8)
    res = run_distributed_lr(task, 4, n_shards=2, policy="dc-array", delta=1,
                             batched=batched)
    assert res.staleness["max_staleness"] <= 1
    assert res.cache["cache_hits"] > 0
    assert res.cache["bytes_saved"] > 0
    init_loss = T.loss(task, np.zeros(task.X.shape[1]))
    assert T.loss(task, res.theta) < init_loss


@pytest.mark.parametrize("batched", [False, True], ids=["per-op", "batched"])
def test_server_ssp_clock_bound(data, batched):
    """SSP on first-class per-worker clocks: the slack bound must hold on
    the merged global history exactly as it does in-process."""
    task = _task(data, n_iters=8)
    res = run_distributed_lr(task, 4, n_shards=2, policy="ssp", delta=2,
                             batched=batched)
    assert H.is_complete(res.history, 4, 8)
    assert ssp_clock_bound_violations(res.history, 4, 2) == []
    assert res.staleness["max_staleness"] <= 2


@pytest.mark.parametrize("batched", [False, True], ids=["per-op", "batched"])
def test_server_op_counts_match_other_backends(data, batched):
    task = _task(data, n_iters=4)
    p = 3
    res = run_distributed_lr(task, p, n_shards=2, policy="hogwild",
                             batched=batched)
    assert res.staleness["reads"] == p * p * task.n_iters
    assert res.staleness["writes"] == p * task.n_iters
    assert H.is_complete(res.history, p, task.n_iters)


def test_server_merged_history_is_order_preserving(data):
    """The global history must be an order-preserving merge of the
    per-shard histories (each shard's local order is authoritative for
    the chunks it owns) — the invariant that makes
    ``is_sequentially_correct`` sound on the merged history."""
    task = _task(data, n_iters=4)
    workers = 4
    slices = T.chunk_slices(task.X.shape[1], workers)
    schedule = task.sample_schedule()
    init = [np.zeros(sl.stop - sl.start) for sl in slices]
    with ShardCluster(init, workers, n_shards=3, policy="dc",
                      delta=0) as cluster:
        import threading

        def worker(i, db):
            for itr in range(1, task.n_iters + 1):
                theta = np.concatenate(db.read_all(i, itr))
                db.write(i, i, itr,
                         T.chunk_update(task, theta, slices[i], itr,
                                        schedule))
            db.close()

        threads = [threading.Thread(
            target=worker, args=(i, cluster.make_client(i)), daemon=True)
            for i in range(workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not any(t.is_alive() for t in threads)
        pulled = cluster.pull()
    parts = [[op for _, op in part] for part in pulled.per_shard]
    assert H.is_order_preserving_merge(pulled.history, parts)
    assert H.is_sequentially_correct(pulled.history, workers)
    # chunk ownership is a partition: each op recorded on exactly one shard
    for shard_idx, part in enumerate(parts):
        from repro.pdb.server import shard_of
        assert all(shard_of(op.chunk, 3) == shard_idx for op in part)
