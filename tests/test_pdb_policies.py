"""Policy-level coverage that the conformance matrix doesn't reach:
the Hogwild (delta=inf) degenerate path, per-chunk delta arrays, and
``SyncConfig.delay_for`` longest-prefix group-delay resolution."""
import math

import numpy as np
import pytest

from repro.core.sync_jax import SyncConfig
from repro.core import threaded as T
from repro.pdb import DeltaPolicy, make_policy, random_schedule


class _Key:
    """Minimal stand-in for jax.tree_util.DictKey."""
    def __init__(self, key):
        self.key = key


# ---------------------------------------------------------------------------
# Hogwild: delta = inf
# ---------------------------------------------------------------------------

class TestHogwild:
    def test_everything_admissible(self):
        d = DeltaPolicy(3, delta=math.inf)
        assert d.hogwild
        for itr in (1, 7, 10 ** 9):
            assert d.can_read(0, 1, itr)
            assert d.can_write(2, 2, itr)

    def test_make_policy_hogwild_alias(self):
        d = make_policy("hogwild", 4)
        assert isinstance(d, DeltaPolicy) and d.hogwild
        # "dc" with delta=inf is the same engine
        d2 = make_policy("dc", 4, delta=math.inf)
        assert isinstance(d2, DeltaPolicy) and d2.hogwild

    def test_random_schedule_total_progress(self):
        """The fuzzer completes under full asynchrony (no admission gating
        means no deadlock and maximal interleaving freedom)."""
        for seed in range(5):
            h = random_schedule("dc", 3, 4, seed=seed, delta=math.inf)
            assert len(h) == 3 * 4 * 4

    def test_hogwild_interleavings_reach_beyond_rcwc(self):
        """With delta=inf some random schedule violates the exact RC/WC
        constraints — the path is genuinely unsynchronized."""
        from repro.core import history as H
        found = False
        for seed in range(20):
            h = random_schedule("dc", 3, 3, seed=seed, delta=math.inf)
            if not H.satisfies_rcwc(h, 3):
                found = True
                break
        assert found

    def test_threaded_hogwild_completes(self):
        X, y = T.make_synthetic_lr(100, 18, seed=1)
        task = T.LRTask(X, y, n_iters=6, mode="gd")
        stats = T.run_parallel(task, 3, policy="hogwild",
                               record_history=True)
        from repro.core import history as H
        assert H.is_complete(stats.history, 3, 6)
        assert np.all(np.isfinite(stats.theta))


# ---------------------------------------------------------------------------
# Per-chunk delta arrays (Sec 7.1 heterogeneous delays)
# ---------------------------------------------------------------------------

class TestPerChunkDelta:
    def test_per_chunk_read_gates(self):
        d = DeltaPolicy(2, delta=[0, 2])
        assert not d.can_read(0, 0, 2)   # chunk 0 exact: version 0 < 1
        assert d.can_read(0, 1, 2)       # chunk 1 tolerates 2 behind
        assert d.can_read(0, 1, 3)
        assert not d.can_read(0, 1, 4)

    def test_scalar_delta_property(self):
        assert DeltaPolicy(2, delta=[1, 3]).delta == 3
        assert DeltaPolicy(2, delta=2).delta == 2

    def test_length_mismatch_rejected(self):
        with pytest.raises(ValueError):
            DeltaPolicy(2, delta=[0, 1], n_chunks=3)

    def test_negative_rejected(self):
        with pytest.raises(ValueError):
            DeltaPolicy(2, delta=[0, -1])


# ---------------------------------------------------------------------------
# SyncConfig.delay_for: longest-prefix group-delay resolution
# ---------------------------------------------------------------------------

class TestDelayFor:
    def test_default_uniform_delta(self):
        s = SyncConfig(delta=3)
        assert s.delay_for((_Key("blocks"), _Key("attn"))) == 3

    def test_exact_prefix_match(self):
        s = SyncConfig(delta=3, group_delays=(("embed", 0),))
        assert s.delay_for((_Key("embed"),)) == 0
        assert s.delay_for((_Key("head"),)) == 3

    def test_longest_prefix_wins(self):
        s = SyncConfig(delta=4, group_delays=(
            ("blocks", 1), ("blocks/0", 2), ("blocks/0/attn", 3)))
        assert s.delay_for((_Key("blocks"), _Key("0"), _Key("attn"))) == 3
        assert s.delay_for((_Key("blocks"), _Key("0"), _Key("mlp"))) == 2
        assert s.delay_for((_Key("blocks"), _Key("7"))) == 1
        assert s.delay_for((_Key("embed"),)) == 4

    def test_order_independent(self):
        a = SyncConfig(delta=4, group_delays=(("b", 1), ("b/0", 2)))
        b = SyncConfig(delta=4, group_delays=(("b/0", 2), ("b", 1)))
        path = (_Key("b"), _Key("0"))
        assert a.delay_for(path) == b.delay_for(path) == 2

    def test_non_key_path_entries_stringify(self):
        s = SyncConfig(delta=1, group_delays=(("layers/3", 0),))
        class Idx:                      # e.g. a SequenceKey-like entry
            def __str__(self):
                return "3"
        assert s.delay_for((_Key("layers"), Idx())) == 0

    def test_to_policy_modes(self):
        from repro.pdb import BSPPolicy, BitVectorPolicy, DeltaPolicy, SSPPolicy
        assert isinstance(SyncConfig(mode="bsp").to_policy(4), BSPPolicy)
        assert isinstance(SyncConfig().to_policy(4), BitVectorPolicy)
        assert isinstance(SyncConfig(delta=2).to_policy(4), DeltaPolicy)
        p = SyncConfig(mode="ssp", delta=2).to_policy(4)
        assert isinstance(p, SSPPolicy) and p.slack == 2
