"""Sharding engine unit tests (no devices needed: pure spec resolution) +
subprocess dry-run on a small forced-device mesh."""
import json
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as PS

from repro.core.sync_jax import ACTIVATION_RULES, RULES, SyncConfig


class FakeMesh:
    """Duck-typed mesh: resolve_spec only uses .shape mapping."""
    def __init__(self, **axes):
        self.shape = axes


from repro.launch.sharding import resolve_spec  # noqa: E402


class TestResolveSpec:
    def setup_method(self):
        self.mesh = FakeMesh(data=16, model=16)
        self.pod_mesh = FakeMesh(pod=2, data=16, model=16)

    def test_basic_tp_fsdp(self):
        spec = resolve_spec(("embed", "ffn"), (4096, 14336), self.mesh,
                            RULES["datacentric"])
        assert spec == PS("data", "model")

    def test_bsp_replicates_embed(self):
        spec = resolve_spec(("embed", "ffn"), (4096, 14336), self.mesh,
                            RULES["bsp"])
        assert spec == PS(None, "model")

    def test_divisibility_fallback(self):
        # 8 experts don't divide a 16-way model axis -> replicate experts,
        # but ffn still shards
        spec = resolve_spec(("experts", "embed", "ffn"), (8, 4096, 14336),
                            self.mesh, RULES["datacentric"])
        assert spec == PS(None, "data", "model")

    def test_expert_parallel_when_divisible(self):
        spec = resolve_spec(("experts", "embed", "ffn"), (16, 5120, 8192),
                            self.mesh, RULES["datacentric"])
        # experts claim `model`; ffn must not reuse it
        assert spec == PS("model", "data", None)

    def test_axis_used_once(self):
        spec = resolve_spec(("ffn", "ffn2"), (7680, 7680), self.mesh,
                            RULES["datacentric"])
        assert spec == PS("model", None)

    def test_batch_hierarchical_dp(self):
        spec = resolve_spec(("batch", "seq"), (256, 4096), self.pod_mesh,
                            ACTIVATION_RULES)
        assert spec == PS(("pod", "data"), None)

    def test_batch_fallback_single_pod(self):
        spec = resolve_spec(("batch", "seq"), (256, 4096), self.mesh,
                            ACTIVATION_RULES)
        assert spec == PS("data", None)

    def test_batch_indivisible_replicates(self):
        spec = resolve_spec(("batch", "seq"), (1, 524288), self.mesh,
                            ACTIVATION_RULES)
        assert spec == PS(None, None)

    def test_kv_cache_sp_fallback(self):
        # kv_seq takes `model` (SP) — kv_heads 8 can't use it afterwards
        spec = resolve_spec(("layers", "batch", "kv_seq", "kv_heads", None),
                            (32, 128, 32768, 8, 128), self.mesh,
                            ACTIVATION_RULES)
        assert spec == PS(None, "data", "model", None, None)


class TestSyncConfig:
    def test_modes(self):
        assert SyncConfig(mode="bsp").param_rules["embed"] == ()
        assert SyncConfig().param_rules["embed"] == ("data",)
        with pytest.raises(ValueError):
            SyncConfig(mode="nope")

    def test_group_delays(self):
        s = SyncConfig(delta=4, group_delays=(("embed", 0), ("groups", 2)))

        class P:  # fake path entry
            def __init__(self, key):
                self.key = key

        assert s.delay_for((P("embed"),)) == 0
        assert s.delay_for((P("groups"), P("g0"))) == 2
        assert s.delay_for((P("final_norm"),)) == 4


DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
from repro.core.sync_jax import SyncConfig
from repro.launch import dryrun
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import tree_shardings, batch_shardings, \\
    opt_state_shardings
from repro.configs import get_smoke_config
from repro.models import paramlib
from repro.models.transformer import model_specs
from repro.optim import OptConfig, make_optimizer
from repro.launch.steps import make_train_step

mesh_shape = {mesh_shape}
axes = {axes}
mesh = jax.make_mesh(mesh_shape, axes)
cfg = get_smoke_config("{arch}")
specs = model_specs(cfg)
params_abs = paramlib.abstract_tree(specs, cfg.param_dtype)
p_shard = tree_shardings(paramlib.axes_tree(specs), params_abs, mesh,
                         SyncConfig().param_rules)
opt = make_optimizer(OptConfig())
step = make_train_step(cfg, opt, SyncConfig())
opt_abs = jax.eval_shape(opt.init, params_abs)
o_shard = opt_state_shardings(p_shard, opt_abs, mesh)
batch_abs = {{"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}}
b_shard = batch_shardings({{"tokens": ("batch", "seq"),
                           "labels": ("batch", "seq")}}, batch_abs, mesh)
with mesh:
    compiled = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                       out_shardings=(p_shard, o_shard, None)) \\
        .lower(params_abs, opt_abs, batch_abs).compile()
coll = dryrun.parse_collective_bytes(compiled.as_text())
print(json.dumps({{"ok": True,
                  "collectives": {{k: v for k, v in coll.items()}}}}))
"""


@pytest.mark.parametrize("mesh_shape,axes", [
    ((4, 2), ("data", "model")),
    ((2, 2, 2), ("pod", "data", "model")),
])
def test_small_mesh_dryrun_subprocess(mesh_shape, axes):
    """lower+compile a reduced config on a forced-device mesh, including the
    multi-pod 3-axis layout, in a subprocess (so the 8 fake devices never
    leak into this test process)."""
    code = DRYRUN_SNIPPET.format(mesh_shape=mesh_shape, axes=axes,
                                 arch="llama3.2-1b")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]
    # a sharded train step must communicate: gradient reduction at minimum
    assert any(k in payload["collectives"]
               for k in ("all-reduce", "reduce-scatter"))
