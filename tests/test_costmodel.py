"""Validate the trip-count correction.

``cost_analysis`` counts while-loop (scan) bodies once, so the raw numbers
undercount by ~n_layers.  The corrected analysis (scan_raw + (n-1) x
per-layer body, where body = fwd + remat-fwd + bwd measured standalone)
must land near the ANALYTIC per-device execution flops:

    full-remat train step ~ 8 * N_active * D_tokens / n_devices
    (2ND fwd + 2ND remat-fwd + 4ND bwd)

The analytic number ignores attention quadratic terms and the CE block, so
we assert a band rather than equality.  Runs in a subprocess with 8 forced
host devices.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax
import jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.core.sync_jax import SyncConfig
from repro.launch.costmodel import corrected_terms, cost_dict, group_body_cost
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.sharding import tree_shardings, batch_shardings
from repro.models import paramlib
from repro.models.config import BlockGroup
from repro.models.transformer import model_specs, lm_loss

mesh = jax.make_mesh((4, 2), ("data", "model"))
sync = SyncConfig(remat="full")
N_LAYERS = 6
cfg = dataclasses.replace(
    get_smoke_config("llama3.2-1b"),
    groups=(BlockGroup(("attn",), N_LAYERS),))
specs = model_specs(cfg)
params_abs = paramlib.abstract_tree(specs, cfg.param_dtype)
p_shard = tree_shardings(paramlib.axes_tree(specs), params_abs, mesh,
                         sync.param_rules)
B, S = 8, 64
batch_abs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
b_shard = batch_shardings({"tokens": ("batch", "seq"),
                           "labels": ("batch", "seq")}, batch_abs, mesh)


def grads_scan(params, batch):
    return jax.grad(lambda p: lm_loss(p, batch, cfg, remat="full")[0])(params)


with mesh:
    compiled = jax.jit(grads_scan, in_shardings=(p_shard, b_shard)) \
        .lower(params_abs, batch_abs).compile()
cost = cost_dict(compiled)
flops_scan = float(cost.get("flops", 0))
bytes_scan = float(cost.get("bytes accessed", 0))

body = group_body_cost(cfg, 0, mesh, sync.param_rules, "train", B, S,
                       "full",
                       lambda t: {k: v for k, v in
                                  parse_collective_bytes(t).items()
                                  if not k.endswith("_count")})
corr = corrected_terms({"cost": {"flops_per_device": flops_scan,
                                 "bytes_per_device": bytes_scan},
                        "collectives": {}}, [body])

n_params = paramlib.param_count(specs)
D = B * S
analytic = 8.0 * n_params * D / 8            # full remat, per device
print(json.dumps({
    "flops_corrected": corr["flops_per_device"],
    "flops_scan_raw": flops_scan,
    "analytic": analytic,
    "body": body["flops"],
}))
"""


@pytest.mark.slow
def test_tripcount_correction_near_analytic():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(SNIPPET)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    # raw scan counting must be a gross undercount vs the corrected number
    assert r["flops_scan_raw"] < 0.45 * r["flops_corrected"]
    # corrected lands near analytic (band: attention quadratic + CE block
    # push it above; sharding padding can push either way)
    ratio = r["flops_corrected"] / r["analytic"]
    assert 0.7 < ratio < 2.0, ratio
