"""Live multi-threaded runtime: the paper's sequential-correctness claim on
real threads (Sec 6 workload)."""
import numpy as np
import pytest

from repro.core import history as H
from repro.core import threaded as T


@pytest.fixture(scope="module")
def data():
    return T.make_synthetic_lr(160, 36, seed=0)


@pytest.mark.parametrize("mode", ["gd", "sgd", "minibatch"])
@pytest.mark.parametrize("workers", [2, 4, 6])
def test_bit_identical_to_sequential(data, mode, workers):
    """delta=0 data-centric == single-thread sequential, bit for bit."""
    X, y = data
    task = T.LRTask(X, y, n_iters=8, mode=mode, batch_size=12, seed=3)
    seq = T.run_sequential(task, workers)
    par = T.run_parallel(task, workers, policy="dc")
    assert np.array_equal(seq, par.theta)


@pytest.mark.parametrize("workers", [2, 5])
def test_bsp_also_bit_identical(data, workers):
    X, y = data
    task = T.LRTask(X, y, n_iters=8, mode="gd")
    seq = T.run_sequential(task, workers)
    par = T.run_parallel(task, workers, policy="bsp")
    assert np.array_equal(seq, par.theta)


def test_recorded_history_is_rcwc_and_sequential(data):
    X, y = data
    task = T.LRTask(X, y, n_iters=6, mode="gd")
    par = T.run_parallel(task, 4, policy="dc", record_history=True)
    h = par.history
    assert H.is_complete(h, 4, 6)
    assert H.satisfies_rcwc(h, 4)
    assert H.is_sequentially_correct(h, 4)


def test_delta_converges_but_may_differ(data):
    """delta>0 relaxes exactness (function-synchronization regime) but must
    still converge on a convex problem."""
    X, y = data
    task = T.LRTask(X, y, n_iters=30, mode="gd", lr=0.3)
    seq = T.run_sequential(task, 4)
    par = T.run_parallel(task, 4, policy="dc", delta=2)
    init_loss = T.loss(task, np.zeros(X.shape[1]))
    assert T.loss(task, par.theta) < 0.9 * init_loss
    # close to (though not necessarily equal to) the exact trajectory
    assert np.linalg.norm(par.theta - seq) < 1.0


def test_chunking_covers_all_features():
    slices = T.chunk_slices(37, 5)
    covered = sorted(i for sl in slices for i in range(sl.start, sl.stop))
    assert covered == list(range(37))


def test_sequential_matches_plain_gd(data):
    """The feature-partitioned sequential execution equals ordinary
    full-vector gradient descent (chunking is semantics-free)."""
    X, y = data
    task = T.LRTask(X, y, n_iters=10, mode="gd")
    theta_chunked = T.run_sequential(task, 6)
    theta = np.zeros(X.shape[1])
    for _ in range(10):
        theta = theta - task.lr * (X.T @ (X @ theta - y)) / X.shape[0]
    np.testing.assert_allclose(theta_chunked, theta, rtol=1e-12)
