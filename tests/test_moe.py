"""MoE layer: routing, capacity, load-balance loss, EP-compatible shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import paramlib
from repro.models.moe import moe_ffn, moe_specs


def _cfg(**kw):
    base = get_smoke_config("mixtral-8x7b")
    return dataclasses.replace(base, dtype=jnp.float32, **kw)


def _params(cfg, seed=0):
    return paramlib.init_tree(moe_specs(cfg), jax.random.PRNGKey(seed))


def test_output_shape_and_finite():
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux["lb_loss"]) > 0


def test_lb_loss_minimal_when_balanced():
    """Uniform router -> lb_loss == 1 (its minimum is 1 for balanced)."""
    cfg = _cfg()
    p = _params(cfg)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])   # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    _, aux = moe_ffn(p, x, cfg)
    # me = 1/E each; ce depends on argmax ties -> lb close to 1
    assert float(aux["lb_loss"]) >= 1.0 - 1e-6


def test_capacity_drops_tokens():
    """With a tiny capacity factor, overflow tokens are dropped (output
    contribution zero) — the Switch/GShard semantics."""
    cfg = _cfg(capacity_factor=0.25, top_k=1)
    p = _params(cfg)
    # force every token to the same expert
    p = dict(p)
    router = np.zeros(p["router"].shape, np.float32)
    router[:, 0] = 10.0
    p["router"] = jnp.asarray(router)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model))
    out, _ = moe_ffn(p, x, cfg)
    # tokens beyond capacity contribute exactly zero
    norms = jnp.linalg.norm(out[0], axis=-1)
    dropped = int(jnp.sum(norms == 0.0))
    assert dropped > 0


def test_high_capacity_no_drops():
    cfg = _cfg(capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, cfg.d_model))
    out, _ = moe_ffn(p, x, cfg)
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert int(jnp.sum(norms == 0.0)) == 0


def test_topk_selects_k_experts():
    cfg = _cfg(capacity_factor=4.0)
    assert cfg.top_k == 2
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))
    out2, _ = moe_ffn(p, x, cfg)
    out1, _ = moe_ffn(p, x, dataclasses.replace(cfg, top_k=1))
    assert not np.allclose(np.asarray(out1), np.asarray(out2))


def test_grouping_is_semantics_free_without_drops():
    """Different dispatch group sizes give identical results when capacity
    is ample (grouping is a perf knob, not semantics)."""
    cfg_a = _cfg(capacity_factor=8.0, moe_group_size=8)
    cfg_b = _cfg(capacity_factor=8.0, moe_group_size=64)
    p = _params(cfg_a)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 32, cfg_a.d_model))
    out_a, _ = moe_ffn(p, x, cfg_a)
    out_b, _ = moe_ffn(p, x, cfg_b)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               rtol=2e-4, atol=2e-5)


def test_grad_flows_through_router():
    cfg = _cfg(capacity_factor=4.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 16, cfg.d_model))

    def loss(params):
        out, aux = moe_ffn(params, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["wg"]).max()) > 0
