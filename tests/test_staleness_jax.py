"""JAX delta-staleness engine: Sec-7 semantics on SPMD-style training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.staleness import init_delayed_state, make_delayed_step
from repro.optim import OptConfig, make_optimizer


def _toy_problem(seed=0, dim=8):
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (32, dim)) / np.sqrt(dim)
    w_true = jax.random.normal(jax.random.PRNGKey(seed + 1), (dim,))
    y = A @ w_true

    def grad_fn(params, batch):
        def loss(p):
            r = batch["A"] @ p["w"] - batch["y"]
            return 0.5 * jnp.mean(r * r)
        l, g = jax.value_and_grad(loss)(params)
        return l, g

    return {"w": jnp.zeros((dim,))}, {"A": A, "y": y}, grad_fn


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_delta0_bit_identical_to_sync(opt_name):
    """The paper's central guarantee mapped to steps: delta=0 == synchronous
    training exactly (both sides jitted — comparing jit to eager would only
    measure XLA fusion noise, not the engine)."""
    params, batch, grad_fn = _toy_problem()
    opt = make_optimizer(OptConfig(name=opt_name, lr=0.1, grad_clip=0,
                                   weight_decay=0.0))

    @jax.jit
    def sync_step(p, s, b):
        _, g = grad_fn(p, b)
        return opt.update(g, s, p)

    p_sync, s_sync = params, opt.init(params)
    for _ in range(10):
        p_sync, s_sync = sync_step(p_sync, s_sync, batch)

    # delayed engine with delta=0
    step = jax.jit(make_delayed_step(grad_fn, opt.update, delta=0))
    state = init_delayed_state(params, opt.init, delta=0)
    for _ in range(10):
        state, m = step(state, batch)

    np.testing.assert_array_equal(np.asarray(p_sync["w"]),
                                  np.asarray(state.params["w"]))


def test_delta_matches_manual_delayed_gd():
    """delta=2 must equal hand-rolled delayed gradient descent:
    w[t+1] = w[t] - lr * grad(w[t-2])."""
    params, batch, grad_fn = _toy_problem(seed=3)
    lr, delta, steps = 0.05, 2, 12
    opt = make_optimizer(OptConfig(name="sgd", lr=lr, grad_clip=0))

    hist = [np.asarray(params["w"])] * (delta + 1)
    w = np.asarray(params["w"])
    for t in range(steps):
        stale = hist[0]
        _, g = grad_fn({"w": jnp.asarray(stale)}, batch)
        w = w - lr * np.asarray(g["w"])
        hist = hist[1:] + [w]

    step = jax.jit(make_delayed_step(grad_fn, opt.update, delta=delta))
    state = init_delayed_state(params, opt.init, delta=delta)
    for _ in range(steps):
        state, _ = step(state, batch)

    np.testing.assert_allclose(np.asarray(state.params["w"]), w,
                               rtol=1e-6, atol=1e-7)


def test_delta_converges_on_convex():
    params, batch, grad_fn = _toy_problem(seed=5)
    opt = make_optimizer(OptConfig(name="sgd", lr=0.2, grad_clip=0))
    step = jax.jit(make_delayed_step(grad_fn, opt.update, delta=3))
    state = init_delayed_state(params, opt.init, delta=3)
    first = None
    for _ in range(60):
        state, m = step(state, batch)
        first = float(m["loss"]) if first is None else first
    assert float(m["loss"]) < 0.2 * first


def _mixed_tree(seed=11):
    """A pytree with several leaves of different shapes/dtypes and a grad_fn
    over all of them — exercises the packed ring's (delay, dtype) grouping."""
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (32, 8)) / np.sqrt(8)
    y = A @ jax.random.normal(jax.random.PRNGKey(seed + 1), (8,))
    params = {
        "w": jnp.zeros((8,)),
        "m": jnp.zeros((4, 8)),
        "b": jnp.zeros((1,)),
        "h": jnp.zeros((8,), jnp.bfloat16),
    }

    def grad_fn(p, batch_):
        def loss(pp):
            w = pp["w"] + pp["m"].mean(0) + pp["h"].astype(jnp.float32)
            r = batch_["A"] @ w + pp["b"] - batch_["y"]
            return 0.5 * jnp.mean(r * r)
        l, g = jax.value_and_grad(loss)(p)
        return l, g

    return params, {"A": A, "y": y}, grad_fn


def _run_trajectory(packed, delta, delay_for=None, steps=8, seed=11,
                    opt_name="adamw"):
    params, batch, grad_fn = _mixed_tree(seed)
    opt = make_optimizer(OptConfig(name=opt_name, lr=0.1, grad_clip=0,
                                   weight_decay=0.0))
    step = jax.jit(make_delayed_step(grad_fn, opt.update, delta=delta,
                                     delay_for=delay_for, packed=packed))
    state = init_delayed_state(params, opt.init, delta=delta, packed=packed,
                               delay_for=delay_for)
    stale0 = step.read_stale(state)
    for _ in range(steps):
        state, m = step(state, batch)
    return stale0, state.params, m


@pytest.mark.parametrize("delta", [0, 1, 2, 3])
def test_packed_ring_bit_identical_to_tree(delta):
    """The packed (delay, dtype)-grouped ring buffer must reproduce the
    per-leaf tree ring exactly — reads and full trajectories, every delta."""
    s_tree, p_tree, _ = _run_trajectory(packed=False, delta=delta)
    s_pack, p_pack, _ = _run_trajectory(packed=True, delta=delta)
    for k in p_tree:
        np.testing.assert_array_equal(np.asarray(s_tree[k]),
                                      np.asarray(s_pack[k]), err_msg=k)
        np.testing.assert_array_equal(np.asarray(p_tree[k]),
                                      np.asarray(p_pack[k]), err_msg=k)


def test_packed_ring_mixed_delays_bit_identical():
    """Per-group delays (Sec 7.1) land leaves in different packed groups;
    the layouts must still agree bit-for-bit."""
    def delay_for(path):
        name = path[0].key if hasattr(path[0], "key") else str(path[0])
        return {"w": 0, "m": 2, "b": 1, "h": 3}[name]

    s_tree, p_tree, _ = _run_trajectory(packed=False, delta=3,
                                        delay_for=delay_for)
    s_pack, p_pack, _ = _run_trajectory(packed=True, delta=3,
                                        delay_for=delay_for)
    for k in p_tree:
        np.testing.assert_array_equal(np.asarray(s_tree[k]),
                                      np.asarray(s_pack[k]), err_msg=k)
        np.testing.assert_array_equal(np.asarray(p_tree[k]),
                                      np.asarray(p_pack[k]), err_msg=k)


def test_packed_ring_pallas_gather_matches_ref(monkeypatch):
    """With REPRO_KERNEL_IMPL=interpret the packed read path runs the Pallas
    ring-gather kernel (emulated) — must stay bit-identical to the XLA ref."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
    _, p_ref, _ = _run_trajectory(packed=True, delta=2, steps=5)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    _, p_int, _ = _run_trajectory(packed=True, delta=2, steps=5)
    for k in p_ref:
        np.testing.assert_array_equal(np.asarray(p_ref[k]),
                                      np.asarray(p_int[k]), err_msg=k)


def test_per_group_delays():
    """Sec-7.1 per-chunk version arrays: different param groups can read
    different staleness levels."""
    params, batch, grad_fn0 = _toy_problem(seed=7)
    params = {"a": params["w"], "b": params["w"] + 1.0}

    def grad_fn(p, batch_):
        def loss(pp):
            r = batch_["A"] @ (pp["a"] + pp["b"]) - batch_["y"]
            return 0.5 * jnp.mean(r * r)
        l, g = jax.value_and_grad(loss)(p)
        return l, g

    opt = make_optimizer(OptConfig(name="sgd", lr=0.1, grad_clip=0))

    def delay_for(path):
        name = path[0].key if hasattr(path[0], "key") else str(path[0])
        return 0 if name == "a" else 2

    step = jax.jit(make_delayed_step(grad_fn, opt.update, delta=2,
                                     delay_for=delay_for))
    state = init_delayed_state(params, opt.init, delta=2)
    for _ in range(5):
        state, m = step(state, batch)
    assert jnp.isfinite(m["loss"])
    # group 'a' read fresh params; 'b' read 2-step-stale ones — verify the
    # trajectories differ from uniform delta in a controlled way
    step_u = jax.jit(make_delayed_step(grad_fn, opt.update, delta=2))
    state_u = init_delayed_state(params, opt.init, delta=2)
    for _ in range(5):
        state_u, _ = step_u(state_u, batch)
    assert not np.allclose(np.asarray(state.params["a"]),
                           np.asarray(state_u.params["a"]))
