"""Discrete-event simulator: Fig-2 trend reproduction + invariants."""
import pytest

from repro.core.simulator import (SimConfig, amdahl_speedup, improvement_pct,
                                  serial_makespan, simulate, trimmed_mean)


def test_dc_beats_bsp_gd_regime():
    imp = improvement_pct(dict(n_workers=16, n_iters=30, seed=0))
    assert imp > 5.0


def test_improvement_grows_with_workers_gd():
    """Fig 2a: 'as the number of workers increases, data-centric
    synchronization gets more opportunity for improvement'."""
    imps = [improvement_pct(dict(n_workers=p, n_iters=30, seed=1))
            for p in (6, 16, 40)]
    assert imps[0] < imps[-1]


def test_sgd_regime_high_improvement_declining():
    """Fig 2e: SGD improvement is high at small p and declines with p."""
    i6 = improvement_pct(dict(n_workers=6, n_iters=30, compute_mu=0.5,
                              seed=0))
    i40 = improvement_pct(dict(n_workers=40, n_iters=30, compute_mu=0.5,
                               seed=0))
    assert i6 > 50.0
    assert i40 < i6


def test_minibatch_declines_less_than_sgd():
    """Fig 2f: 'the decline is much more pronounced in SGD whereas it is
    not as sharp under mini-batch'."""
    def decline(mu):
        a = improvement_pct(dict(n_workers=6, n_iters=30, compute_mu=mu,
                                 seed=2))
        b = improvement_pct(dict(n_workers=40, n_iters=30, compute_mu=mu,
                                 seed=2))
        return a - b
    assert decline(0.5) > decline(2.5)


def test_delta_absorbs_stragglers():
    base = dict(n_workers=16, n_iters=30, straggler_prob=0.05, seed=1)
    i0 = improvement_pct(base, delta=0)
    i2 = improvement_pct(base, delta=2)
    assert i2 > i0 + 10.0


def test_backup_tasks_cap_stragglers():
    cfg = dict(n_workers=16, n_iters=30, straggler_prob=0.05,
               straggler_factor=20.0, seed=3)
    plain = simulate(SimConfig(policy="dc", **cfg))
    backed = simulate(SimConfig(policy="dc", backup_tasks=True, **cfg))
    assert backed.makespan < plain.makespan


def test_deterministic():
    cfg = SimConfig(n_workers=8, n_iters=20, seed=5)
    assert simulate(cfg).makespan == simulate(cfg).makespan


def test_same_workload_across_policies():
    """Both policies see identical compute draws — differences are pure
    synchronization effects."""
    a = simulate(SimConfig(policy="bsp", n_workers=8, n_iters=10, seed=7,
                           read_cost=0, write_cost=0, barrier_cost=0,
                           barrier_base=0, check_cost=0))
    b = simulate(SimConfig(policy="dc", n_workers=8, n_iters=10, seed=7,
                           read_cost=0, write_cost=0, barrier_cost=0,
                           barrier_base=0, check_cost=0))
    # with zero sync costs both reduce to sum of per-iteration maxima
    assert a.makespan == pytest.approx(b.makespan, rel=1e-9)


def test_speedup_below_amdahl():
    cfg = SimConfig(policy="dc", n_workers=16, n_iters=30, seed=0)
    sp = serial_makespan(cfg) / simulate(cfg).makespan
    assert 1.0 < sp < 16.0
    assert sp < amdahl_speedup(16, 0.01) * 1.05


def test_trimmed_mean_drops_extremes():
    xs = [100.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 0.0]
    assert trimmed_mean(xs) == pytest.approx(sum(range(2, 8)) / 6 + 0.0,
                                             rel=1e-9)
