"""Paged serving cache: allocator bookkeeping and decode equivalence.

The load-bearing property is that the paged cache is *invisible* to the
model: batch decode through page tables must be token-identical to
per-sequence dense decode (same prefill, same positions), across full
attention, windowed attention and recurrent state — and must stay so
through evict/rejoin churn, since continuous batching reuses pages from
finished sequences mid-stream.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import paramlib
from repro.models.transformer import decode_step, model_specs, prefill
from repro.serve import (PageAllocator, init_paged_cache, make_evict_fn,
                         make_join_fn, page_classes)

CACHE_LEN, PAGE = 32, 8
# attn-only, two page classes (window 16 + full 32), recurrent+windowed
ARCHS = ("llama3.2-1b", "gemma3-4b", "recurrentgemma-2b")


def _model(arch):
    cfg = get_smoke_config(arch)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0),
                                dtype=cfg.param_dtype)
    return cfg, params


def _dense_tokens(cfg, params, prompt, n_steps):
    """Per-sequence (B=1) dense-ring greedy decode — the oracle."""
    logits, cache = prefill(params, jnp.asarray([prompt], jnp.int32), cfg,
                            cache_len=CACHE_LEN)
    tok, pos = int(jnp.argmax(logits[0])), len(prompt)
    toks = [tok]
    for _ in range(n_steps):
        lg, cache = decode_step(params, cache,
                                jnp.asarray([[tok]], jnp.int32),
                                jnp.asarray(pos, jnp.int32), cfg)
        tok = int(jnp.argmax(lg[0, -1]))
        toks.append(tok)
        pos += 1
    return toks


def _join_seq(cfg, params, alloc, join, cache, b, prompt, tok, pos):
    logits, dense = prefill(params, jnp.asarray([prompt], jnp.int32), cfg,
                            cache_len=CACHE_LEN)
    rows = {L: jnp.asarray(ids) for L, ids in alloc.alloc(b).items()}
    cache = join(cache, dense, jnp.asarray(b, jnp.int32), rows)
    tok[b, 0] = int(jnp.argmax(logits[0]))
    pos[b] = len(prompt)
    return cache


class TestPageClasses:
    def test_indivisible_page_size_rejected(self):
        cfg = get_smoke_config("llama3.2-1b")
        with pytest.raises(ValueError, match="must divide"):
            page_classes(cfg, cache_len=32, page_size=5)

    def test_window_and_full_classes(self):
        cfg = get_smoke_config("gemma3-4b")          # window 16 + full attn
        assert page_classes(cfg, 32, 8) == {16: 2, 32: 4}


class TestPageAllocator:
    def test_churn_and_reuse(self):
        cfg = get_smoke_config("llama3.2-1b")
        alloc = PageAllocator(cfg, batch=3, cache_len=CACHE_LEN,
                              page_size=PAGE)
        (L, npp), = alloc.classes.items()
        total = 3 * npp
        rows0 = alloc.alloc(0)
        rows1 = alloc.alloc(1)
        assert alloc.n_free(L) == total - 2 * npp
        assert not set(rows0[L]) & set(rows1[L])     # disjoint pages
        assert alloc.junk[L] not in set(rows0[L]) | set(rows1[L])
        alloc.free_slot(0)
        assert alloc.n_free(L) == total - npp
        rows2 = alloc.alloc(2)                       # reuses freed pages
        assert set(rows2[L]) == set(rows0[L])
        assert (alloc.tables[L][0] == alloc.junk[L]).all()

    def test_double_alloc_and_exhaustion(self):
        cfg = get_smoke_config("llama3.2-1b")
        alloc = PageAllocator(cfg, batch=2, cache_len=CACHE_LEN,
                              page_size=PAGE)
        alloc.alloc(0)
        with pytest.raises(ValueError, match="already holds"):
            alloc.alloc(0)
        (L,) = alloc.classes
        alloc.free[L].clear()                        # pool drained
        with pytest.raises(RuntimeError, match="exhausted"):
            alloc.alloc(1)


class TestPagedDecode:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_matches_dense_per_sequence(self, arch):
        """Batched paged decode == per-sequence dense decode, greedy
        token for token (row independence + page indirection exactness)."""
        cfg, params = _model(arch)
        rng = np.random.default_rng(0)
        prompts = [tuple(int(t) for t in rng.integers(0, cfg.vocab_size, n))
                   for n in (4, 6)]
        n_steps = 4
        want = [_dense_tokens(cfg, params, p, n_steps) for p in prompts]

        B = len(prompts)
        alloc = PageAllocator(cfg, B, CACHE_LEN, PAGE)
        cache = init_paged_cache(cfg, B, CACHE_LEN, PAGE)
        join = jax.jit(make_join_fn(cfg, CACHE_LEN, PAGE))
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        for b, p in enumerate(prompts):
            cache = _join_seq(cfg, params, alloc, join, cache, b, p, tok,
                              pos)
        got = [[int(t)] for t in tok[:, 0]]
        for _ in range(n_steps):
            lg, cache = decode_step(params, cache, jnp.asarray(tok),
                                    jnp.asarray(pos), cfg)
            nxt = np.asarray(jnp.argmax(lg[:, -1], -1))
            for b in range(B):
                got[b].append(int(nxt[b]))
                tok[b, 0] = int(nxt[b])
                pos[b] += 1
        assert got == want

    def test_evict_rejoin_roundtrip(self):
        """Evicting a slot and rejoining a new sequence onto recycled
        pages must not perturb the surviving sequence, and the rejoined
        sequence must decode exactly as it would alone."""
        cfg, params = _model("gemma3-4b")
        rng = np.random.default_rng(1)
        p0 = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 6))
        p1 = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 4))
        p2 = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 6))
        want0 = _dense_tokens(cfg, params, p0, 6)
        want2 = _dense_tokens(cfg, params, p2, 2)

        B = 2
        alloc = PageAllocator(cfg, B, CACHE_LEN, PAGE)
        cache = init_paged_cache(cfg, B, CACHE_LEN, PAGE)
        join = jax.jit(make_join_fn(cfg, CACHE_LEN, PAGE))
        evict = jax.jit(make_evict_fn(cfg, CACHE_LEN, PAGE))
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        cache = _join_seq(cfg, params, alloc, join, cache, 0, p0, tok, pos)
        cache = _join_seq(cfg, params, alloc, join, cache, 1, p1, tok, pos)
        got0 = [int(tok[0, 0])]

        def step():
            nonlocal cache
            lg, cache = decode_step(params, cache, jnp.asarray(tok),
                                    jnp.asarray(pos), cfg)
            nxt = np.asarray(jnp.argmax(lg[:, -1], -1))
            for b in range(B):
                tok[b, 0] = int(nxt[b])
                pos[b] += 1
            return nxt

        for _ in range(3):
            got0.append(int(step()[0]))
        # sequence 1 leaves mid-decode; its pages go back to the free list
        cache = evict(cache, jnp.asarray(1, jnp.int32))
        alloc.free_slot(1)
        tok[1, 0] = 0
        pos[1] = 0
        got0.append(int(step()[0]))     # survivor decodes with idle row
        # a new sequence rejoins onto the recycled pages
        cache = _join_seq(cfg, params, alloc, join, cache, 1, p2, tok, pos)
        got2 = [int(tok[1, 0])]
        for _ in range(2):
            nxt = step()
            got0.append(int(nxt[0]))
            got2.append(int(nxt[1]))
        assert got0 == want0[:7]
        assert got2 == want2


class TestRefcounts:
    """Shared-page accounting: a page returns to the free list only when
    its last owner (slot table or prefix trie) drops it."""

    def test_shared_page_survives_free_slot(self):
        cfg = get_smoke_config("llama3.2-1b")
        alloc = PageAllocator(cfg, batch=2, cache_len=CACHE_LEN,
                              page_size=PAGE, extra_seqs=1)
        (L, npp), = alloc.classes.items()
        rows = alloc.alloc(0)
        shared = int(rows[L][0])
        alloc.incref(L, shared)              # a second owner (the trie)
        free_before = alloc.n_free(L)
        alloc.free_slot(0)
        # all but the shared page returned
        assert alloc.n_free(L) == free_before + npp - 1
        assert shared not in alloc.free[L]
        alloc.decref(L, shared)              # last owner drops it
        assert shared in alloc.free[L]
        assert alloc.refcount[L][shared] == 0

    def test_install_adopted_rows(self):
        """install() records externally assembled rows (adopted pages +
        fresh ones) and free_slot() releases exactly one ref each."""
        cfg = get_smoke_config("llama3.2-1b")
        alloc = PageAllocator(cfg, batch=2, cache_len=CACHE_LEN,
                              page_size=PAGE, extra_seqs=1)
        (L, npp), = alloc.classes.items()
        donor = alloc.alloc(0)
        adopted = int(donor[L][0])
        alloc.incref(L, adopted)             # slot 1's lease on the page
        fresh = alloc.alloc_pages(L, npp - 1)
        row = np.concatenate([[adopted], fresh]).astype(np.int32)
        alloc.install(1, {L: row})
        with pytest.raises(ValueError, match="already holds"):
            alloc.install(1, {L: row})
        assert alloc.refcount[L][adopted] == 2
        alloc.free_slot(1)
        assert alloc.refcount[L][adopted] == 1   # donor still owns it
        assert all(alloc.refcount[L][p] == 0 for p in fresh)

    def test_headroom_capacity(self):
        cfg = get_smoke_config("llama3.2-1b")
        alloc = PageAllocator(cfg, batch=2, cache_len=CACHE_LEN,
                              page_size=PAGE, extra_seqs=2)
        (L, npp), = alloc.classes.items()
        alloc.alloc(0)
        alloc.alloc(1)
        assert alloc.n_free(L) == 2 * npp    # extra_seqs' worth left over


class TestPrefixCacheTrie:
    """Host-side radix trie over token pages: lookup/insert/lease/evict."""

    def _alloc(self, extra=2):
        cfg = get_smoke_config("llama3.2-1b")
        from repro.serve import PrefixCache
        alloc = PageAllocator(cfg, batch=2, cache_len=CACHE_LEN,
                              page_size=PAGE, extra_seqs=extra)
        return alloc, PrefixCache(alloc, PAGE)

    def _publish(self, alloc, trie, prompt, b=0):
        rows = alloc.alloc(b)
        path, new_idx = trie.insert(prompt, rows)
        return rows, path, new_idx

    def test_lookup_full_partial_and_cap(self):
        alloc, trie = self._alloc()
        prompt = tuple(range(100, 100 + 24))          # 3 full pages
        rows, path, new_idx = self._publish(alloc, trie, prompt)
        assert len(path) == 3 and new_idx == [0, 1, 2]
        # identical prompt: adoption capped at len-1 -> 2 full + partial 7
        full, partial = trie.lookup(prompt)
        assert len(full) == 2 and partial is not None
        assert partial[1] == PAGE - 1
        # diverging mid-page-2: 1 full + partial of the matched tokens
        div = prompt[:12] + (7, 7) + prompt[14:]
        full, partial = trie.lookup(div)
        assert len(full) == 1 and partial[1] == 4
        # diverging in page 0: no full nodes, partial only
        full, partial = trie.lookup((prompt[0], 9, 9, 9, 9, 9, 9, 9, 1, 2))
        assert full == [] and partial[1] == 1
        # disjoint prompt: clean miss
        full, partial = trie.lookup(tuple(range(500, 524)))
        assert full == [] and partial is None
        assert 0.0 < trie.hit_rate < 1.0

    def test_lease_blocks_eviction(self):
        alloc, trie = self._alloc()
        (L,) = alloc.classes
        prompt = tuple(range(16))
        rows, path, _ = self._publish(alloc, trie, prompt)
        alloc.free_slot(0)                   # trie is now the only owner
        full, _ = trie.lookup(prompt + (1, 2, 3))
        trie.lease(full)
        trie.evict_for(L, 10 ** 9)           # "evict everything you can"
        assert trie.n_nodes == 2             # leased path survives
        trie.release(full)
        for p in full:                       # drop the lease's page refs
            alloc.decref(L, p.pages[L])
        trie.release(path)                   # inserting slot retires
        trie.evict_for(L, 10 ** 9)
        assert trie.n_nodes == 0             # now LRU-evictable

    def test_eviction_roundtrip_under_pressure(self):
        """Keep publishing distinct prompts through a small pool: evict
        must recycle trie pages so allocation always succeeds, and every
        page ends the churn exactly once-owned or free."""
        alloc, trie = self._alloc(extra=1)
        (L, npp), = alloc.classes.items()
        for i in range(6):
            trie.evict_for(L, npp)
            prompt = tuple(range(i * 50, i * 50 + 16))
            rows, path, _ = self._publish(alloc, trie, prompt, b=0)
            trie.release(path)               # slot retires immediately
            alloc.free_slot(0)
        assert trie.n_nodes > 0
        total = (alloc.batch + 1) * npp
        held = sum(int(alloc.refcount[L][p]) for p in range(total))
        assert held + alloc.n_free(L) == total
        assert all(alloc.refcount[L][p] in (0, 1) for p in range(total))


class TestChunkedPrefill:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_chunks_plus_activate_match_dense(self, arch):
        """Chunked prefill into junk-tabled pages + activation must
        decode token-identically to dense whole-prompt prefill + join —
        across full attention, windowed rings and recurrent carries."""
        from repro.models.transformer import init_chunk_carry, prefill_chunk
        from repro.serve import make_activate_fn
        cfg, params = _model(arch)
        rng = np.random.default_rng(7)
        C, S, n_steps = 8, 16, 4
        prompt = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, S))
        want = _dense_tokens(cfg, params, prompt, n_steps)

        B = 2
        alloc = PageAllocator(cfg, B, CACHE_LEN, PAGE)
        cache = init_paged_cache(cfg, B, CACHE_LEN, PAGE)
        activate = jax.jit(make_activate_fn(cfg, CACHE_LEN, PAGE))
        rows = {L: jnp.asarray(ids) for L, ids in alloc.alloc(1).items()}
        carry = init_chunk_carry(cfg)
        logits = None
        for s0 in range(0, S, C):
            toks = jnp.asarray([prompt[s0:s0 + C]], jnp.int32)
            logits, cache, carry = prefill_chunk(
                params, cache, toks, jnp.asarray(s0, jnp.int32), rows,
                carry, cfg, CACHE_LEN)
        cache = activate(cache, jnp.asarray(1, jnp.int32), rows, carry)
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        tok[1, 0] = int(jnp.argmax(logits[0]))
        pos[1] = S
        got = [int(tok[1, 0])]
        for _ in range(n_steps):
            lg, cache = decode_step(params, cache, jnp.asarray(tok),
                                    jnp.asarray(pos), cfg)
            tok[1, 0] = int(jnp.argmax(lg[1, -1]))
            pos[1] += 1
            got.append(int(tok[1, 0]))
        assert got == want
