"""Unit tests for the Sec-5 / Sec-7.1 admission protocols."""
import math

import pytest

from repro.core.scheduler import (BSPScheduler, BitVectorScheduler,
                                  DeltaScheduler, random_schedule)


class TestBitVector:
    def test_initial_reads_allowed(self):
        s = BitVectorScheduler(3)
        for i in range(3):
            for j in range(3):
                assert s.can_read(i, j, 1)

    def test_read_ahead_blocked(self):
        s = BitVectorScheduler(2)
        assert not s.can_read(0, 1, 2)   # chunk 1 not yet written for iter 1

    def test_write_requires_all_reads(self):
        """'a write on pi_i can be executed if this chunk has been read by
        all the worker processes in their alpha-th iterations'."""
        s = BitVectorScheduler(2)
        s.did_read(0, 0, 1)
        assert not s.can_write(0, 0, 1)  # worker 1 hasn't read chunk 0
        s.did_read(1, 0, 1)
        assert s.can_write(0, 0, 1)

    def test_write_zeroes_bits(self):
        s = BitVectorScheduler(2)
        for w in range(2):
            s.did_read(w, 0, 1)
        s.did_write(0, 0, 1)
        assert s.bits[0] == [False, False]
        assert s.version[0] == 1
        assert not s.can_write(0, 0, 2)

    def test_read_version_gate(self):
        """'read can be executed if the iteration number in the read
        operation is one more than the iteration number of the chunk'."""
        s = BitVectorScheduler(2)
        for w in range(2):
            s.did_read(w, 0, 1)
        s.did_write(0, 0, 1)
        assert s.can_read(1, 0, 2)
        assert not s.can_read(1, 0, 3)


class TestDelta:
    def test_delta0_equals_bitvector(self):
        b = BitVectorScheduler(3)
        d = DeltaScheduler(3, delta=0)
        ops = [("r", 0, 0, 1), ("r", 1, 0, 1), ("r", 2, 0, 1)]
        for _, w, c, a in ops:
            assert b.can_read(w, c, a) == d.can_read(w, c, a)
            b.did_read(w, c, a)
            d.did_read(w, c, a)
        assert b.can_write(0, 0, 1) == d.can_write(0, 0, 1) is True

    def test_stale_read_allowed(self):
        d = DeltaScheduler(2, delta=1)
        # chunk 1 never written, but version 0 >= 2-1-1 = 0
        assert d.can_read(0, 1, 2)
        assert not d.can_read(0, 1, 3)

    def test_write_min_gate(self):
        """'write can be executed if the slowest worker to read this chunk
        is no more than delta iterations behind'."""
        d = DeltaScheduler(2, delta=1)
        d.did_read(0, 0, 2)
        d.did_read(1, 0, 1)                  # slowest reader at iter 1
        assert d.can_write(0, 0, 2)          # 1 >= 2 - 1
        assert not d.can_write(0, 0, 3)      # 1 <  3 - 1

    def test_hogwild_limit(self):
        d = DeltaScheduler(2, delta=math.inf)
        assert d.hogwild
        assert d.can_read(0, 1, 10 ** 6)
        assert d.can_write(0, 0, 10 ** 6)

    def test_negative_delta_rejected(self):
        with pytest.raises(ValueError):
            DeltaScheduler(2, delta=-1)


class TestBSP:
    def test_read_barrier(self):
        s = BSPScheduler(2)
        assert s.can_read(0, 0, 1)
        assert not s.can_read(0, 0, 2)       # nobody wrote iter 1
        s.did_write(0, 0, 1)
        assert not s.can_read(0, 0, 2)       # worker 1 still hasn't
        s.did_write(1, 1, 1)
        assert s.can_read(0, 0, 2)

    def test_write_barrier_global(self):
        s = BSPScheduler(2)
        for j in range(2):
            s.did_read(0, j, 1)
        assert not s.can_write(0, 0, 1)      # worker 1's reads missing
        for j in range(2):
            s.did_read(1, j, 1)
        assert s.can_write(0, 0, 1)


class TestProgress:
    """Deadlock freedom: the random scheduler always completes."""

    @pytest.mark.parametrize("policy", ["bsp", "dc", "dc-array"])
    @pytest.mark.parametrize("p,n", [(2, 3), (4, 3), (6, 2)])
    def test_total_progress(self, policy, p, n):
        for seed in range(5):
            h = random_schedule(policy, p, n, seed=seed)
            assert len(h) == p * n * (p + 1)

    def test_progress_with_delta(self):
        for seed in range(5):
            h = random_schedule("dc", 3, 4, seed=seed, delta=2)
            assert len(h) == 3 * 4 * 4
