"""Serving-path integration: prefill + teacher-forced decode must equal the
full forward pass exactly (f32, ample MoE capacity), for every arch — and
for every kernel impl: the XLA reference and the Pallas kernels in
interpret mode (fused decode attention + grouped MoE) must give the same
serving-path answer.  Interpret mode is a Python emulator, so the Pallas
sweep is restricted to the two GQA configs the fused decode kernel is
built for (llama3.2: 32q/8kv family; mixtral: GQA + MoE + SWA ring)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_ids, get_smoke_config
from repro.models import paramlib
from repro.models.transformer import (decode_step, forward, model_specs,
                                      prefill)

PALLAS_ARCHS = ("llama3.2-1b", "mixtral-8x7b")

IMPL_CASES = [("ref", a) for a in all_arch_ids()] + \
             [("interpret", a) for a in PALLAS_ARCHS]


def _roundtrip(arch, B=2, S=24, extra=3, window=None):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype=jnp.float32,
                              capacity_factor=4.0)
    if window is not None:
        cfg = dataclasses.replace(cfg, window=window)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S + extra), 0,
                              cfg.vocab_size)
    media = None
    if cfg.frontend == "vision":
        media = jax.random.normal(
            jax.random.PRNGKey(1),
            (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.float32)

    full_logits, _ = forward(params, toks, cfg, media=media)
    last, cache = prefill(params, toks[:, :S], cfg, cache_len=S + extra,
                          media=media)
    assert float(jnp.abs(last - full_logits[:, S - 1]).max()) < 2e-3
    for t in range(extra):
        dl, cache = decode_step(params, cache, toks[:, S + t:S + t + 1],
                                jnp.asarray(S + t, jnp.int32), cfg,
                                media=media)
        err = float(jnp.abs(dl[:, 0] - full_logits[:, S + t]).max())
        assert err < 2e-3, (arch, t, err)


@pytest.mark.parametrize("impl,arch", IMPL_CASES)
def test_prefill_decode_matches_forward(impl, arch, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    _roundtrip(arch)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_windowed_ring_buffer_wraps(impl, monkeypatch):
    """Decode far past the window: ring buffer must keep exactly the last
    `window` positions (gemma3 local layers)."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    cfg = dataclasses.replace(get_smoke_config("gemma3-4b"),
                              dtype=jnp.float32, window=8)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    B, S, extra = 1, 16, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = forward(params, toks, cfg)
    last, cache = prefill(params, toks[:, :S], cfg, cache_len=S + extra)
    for t in range(extra):
        dl, cache = decode_step(params, cache, toks[:, S + t:S + t + 1],
                                jnp.asarray(S + t, jnp.int32), cfg)
        err = float(jnp.abs(dl[:, 0] - full_logits[:, S + t]).max())
        assert err < 2e-3, (t, err)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_gqa_ring_wrap_past_cache(impl, monkeypatch):
    """mixtral smoke: SWA ring of length `window`=16, decoded to positions
    pos >= cache ring length, under both kernel impls — the fused decode
    kernel sees wrapped slots (slot = pos % L) with the window mask."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)
    # prompt 16 + 6 generated: decode positions 16..21 all wrap the L=16
    # swa ring (pos >= cache_len for the windowed cache)
    _roundtrip("mixtral-8x7b", B=1, S=16, extra=6)
