"""Serving-path integration: prefill + teacher-forced decode must equal the
full forward pass exactly (f32, ample MoE capacity), for every arch."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_ids, get_smoke_config
from repro.models import paramlib
from repro.models.transformer import (decode_step, forward, model_specs,
                                      prefill)


@pytest.mark.parametrize("arch", all_arch_ids())
def test_prefill_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype=jnp.float32,
                              capacity_factor=4.0)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    B, S, extra = 2, 24, 3
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S + extra), 0,
                              cfg.vocab_size)
    media = None
    if cfg.frontend == "vision":
        media = jax.random.normal(
            jax.random.PRNGKey(1),
            (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.float32)

    full_logits, _ = forward(params, toks, cfg, media=media)
    last, cache = prefill(params, toks[:, :S], cfg, cache_len=S + extra,
                          media=media)
    assert float(jnp.abs(last - full_logits[:, S - 1]).max()) < 2e-3
    for t in range(extra):
        dl, cache = decode_step(params, cache, toks[:, S + t:S + t + 1],
                                jnp.asarray(S + t, jnp.int32), cfg,
                                media=media)
        err = float(jnp.abs(dl[:, 0] - full_logits[:, S + t]).max())
        assert err < 2e-3, (arch, t, err)


def test_windowed_ring_buffer_wraps():
    """Decode far past the window: ring buffer must keep exactly the last
    `window` positions (gemma3 local layers)."""
    cfg = dataclasses.replace(get_smoke_config("gemma3-4b"),
                              dtype=jnp.float32, window=8)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    B, S, extra = 1, 16, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = forward(params, toks, cfg)
    last, cache = prefill(params, toks[:, :S], cfg, cache_len=S + extra)
    for t in range(extra):
        dl, cache = decode_step(params, cache, toks[:, S + t:S + t + 1],
                                jnp.asarray(S + t, jnp.int32), cfg)
        err = float(jnp.abs(dl[:, 0] - full_logits[:, S + t]).max())
        assert err < 2e-3, (t, err)
