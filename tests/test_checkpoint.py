"""Checkpointing: roundtrip, atomicity, shard splitting, resume exactness."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.checkpoint as ck
from repro.checkpoint import (latest_step, load_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "opt": {"m": jnp.ones((16, 8)), "step": jnp.asarray(7)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    got = load_checkpoint(str(tmp_path), 5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step(tmp_path):
    assert latest_step(str(tmp_path)) is None
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    save_checkpoint(str(tmp_path), 9, t)
    assert latest_step(str(tmp_path)) == 9


def test_tmp_dirs_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    os.makedirs(tmp_path / "step_99.tmp")      # simulated crash mid-write
    assert latest_step(str(tmp_path)) == 3


def test_shard_splitting(tmp_path, monkeypatch):
    monkeypatch.setattr(ck, "_SHARD_BYTES", 128)   # force splitting
    t = {"big": jnp.arange(400, dtype=jnp.float32).reshape(20, 20)}
    save_checkpoint(str(tmp_path), 1, t)
    files = os.listdir(tmp_path / "step_1")
    assert sum(f.startswith("0.s") for f in files) > 1
    got = load_checkpoint(str(tmp_path), 1, t)
    np.testing.assert_array_equal(np.asarray(got["big"]), np.asarray(t["big"]))


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    wrong = {"params": {"w": jnp.zeros((4, 4)),
                        "b": jnp.zeros((8,), jnp.bfloat16)},
             "opt": {"m": jnp.ones((16, 8)), "step": jnp.asarray(0)}}
    with pytest.raises(ValueError, match="shape mismatch"):
        load_checkpoint(str(tmp_path), 1, wrong)


def test_resume_bit_exact(tmp_path):
    """Training N steps == training k, checkpoint, restore, train N-k."""
    from repro.configs import get_smoke_config
    from repro.core.sync_jax import SyncConfig
    from repro.data import LMBatchSpec, make_lm_batch
    from repro.launch.steps import make_train_step
    from repro.models import paramlib
    from repro.models.transformer import model_specs
    from repro.optim import OptConfig, make_optimizer

    cfg = get_smoke_config("llama3.2-1b")
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig(lr=1e-3))
    step = jax.jit(make_train_step(cfg, opt, SyncConfig()))
    spec = LMBatchSpec(batch=2, seq_len=32, vocab_size=cfg.vocab_size, seed=4)

    # uninterrupted
    p1, s1 = params, opt.init(params)
    for t in range(6):
        p1, s1, _ = step(p1, s1, make_lm_batch(spec, t))

    # interrupted at 3 + resumed
    p2, s2 = params, opt.init(params)
    for t in range(3):
        p2, s2, _ = step(p2, s2, make_lm_batch(spec, t))
    save_checkpoint(str(tmp_path), 3, {"p": p2, "s": s2})
    loaded = load_checkpoint(str(tmp_path), 3, {"p": p2, "s": s2})
    p2 = jax.tree.map(jnp.asarray, loaded["p"])
    s2 = jax.tree.map(jnp.asarray, loaded["s"])
    for t in range(3, 6):
        p2, s2, _ = step(p2, s2, make_lm_batch(spec, t))

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_stream_deterministic():
    from repro.data import LMBatchSpec, make_lm_batch
    spec = LMBatchSpec(batch=2, seq_len=16, vocab_size=97, seed=11)
    a = make_lm_batch(spec, 42)
    b = make_lm_batch(spec, 42)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = make_lm_batch(spec, 43)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
