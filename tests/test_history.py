"""Machine-checked versions of the paper's Theorems 1-3 and Fig-3 examples,
via hypothesis property testing over scheduler-generated executions."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import history as H
from repro.core.scheduler import random_schedule

WORKERS = st.integers(min_value=2, max_value=5)
ITERS = st.integers(min_value=1, max_value=4)
SEEDS = st.integers(min_value=0, max_value=10_000)


# ---------------------------------------------------------------------------
# Paper's own examples (Fig 1, Fig 3)
# ---------------------------------------------------------------------------

class TestPaperExamples:
    def test_h1_is_bsp_and_rcwc(self):
        h1 = H.normalize_history(H.paper_h1())
        assert H.satisfies_bsp(h1, 2)
        assert H.satisfies_rcwc(h1, 2)
        assert H.is_sequentially_correct(h1, 2)

    def test_h2_is_rcwc_but_not_bsp(self):
        """H2 is 'one of the several more executions possible by relaxing
        the barrier conditions' — Theorem 3's strictness witness."""
        h2 = H.normalize_history(H.paper_h2())
        assert not H.satisfies_bsp(h2, 2)
        assert H.satisfies_rcwc(h2, 2)
        assert H.is_sequentially_correct(h2, 2)

    def test_h3_rejected(self):
        """H3 is 'permitted neither by the BSP nor the RC and WC'."""
        h3 = H.normalize_history(H.paper_h3())
        assert not H.satisfies_bsp(h3, 2)
        assert not H.satisfies_rcwc(h3, 2)
        assert not H.is_sequentially_correct(h3, 2)

    def test_h2_semantically_equal_h3_not(self):
        upd = H.default_update(2, 3, seed=1)
        seq = H.sequential_result(2, 2, 3, upd)
        h2 = H.normalize_history(H.paper_h2())
        h3 = H.normalize_history(H.paper_h3())
        assert np.allclose(H.execute_history(h2, 2, 3, upd), seq)
        assert not np.allclose(H.execute_history(h3, 2, 3, upd), seq)

    def test_seq_executions_fig1(self):
        seq1 = H.sequential_history(2, 2)
        assert H.is_strictly_sequential(seq1, 2)
        assert H.is_sequentially_correct(seq1, 2)


# ---------------------------------------------------------------------------
# Theorem 1: BSP => sequential ML computation
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(p=WORKERS, n=ITERS, seed=SEEDS)
def test_bsp_schedules_are_sequential(p, n, seed):
    h = random_schedule("bsp", p, n, seed=seed)
    assert H.is_complete(h, p, n)
    assert H.satisfies_bsp(h, p)
    assert H.is_sequentially_correct(h, p)


# ---------------------------------------------------------------------------
# Theorem 2: RC/WC => sequential ML computation (syntactic AND semantic)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(p=WORKERS, n=ITERS, seed=SEEDS)
def test_rcwc_schedules_are_sequential(p, n, seed):
    h = random_schedule("dc", p, n, seed=seed)
    assert H.is_complete(h, p, n)
    assert H.satisfies_rcwc(h, p)
    assert H.is_sequentially_correct(h, p)


@settings(max_examples=25, deadline=None)
@given(p=st.integers(2, 4), n=st.integers(1, 3), seed=SEEDS)
def test_rcwc_schedules_semantically_equal_sequential(p, n, seed):
    """The strong form: executing any RC/WC-admissible interleaving against
    a non-commuting numeric update gives exactly the sequential answer."""
    h = random_schedule("dc", p, n, seed=seed)
    dim = 2
    upd = H.default_update(p, dim, seed=seed % 17)
    got = H.execute_history(h, p, dim, upd)
    want = H.sequential_result(p, n, dim, upd)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Theorem 3: BSP executions ⊆ RC/WC executions (and strictly so)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(p=WORKERS, n=ITERS, seed=SEEDS)
def test_bsp_subset_rcwc(p, n, seed):
    h = random_schedule("bsp", p, n, seed=seed)
    assert H.satisfies_rcwc(h, p)        # every BSP execution is RC/WC


def test_rcwc_strictly_larger():
    """Find an RC/WC execution that BSP forbids (H2 is one; fuzzing finds
    more) — the 'more possible executions' half of Theorem 3."""
    found = 0
    for seed in range(200):
        h = random_schedule("dc", 3, 2, seed=seed)
        if not H.satisfies_bsp(h, 3):
            found += 1
    assert found > 0, "no RC/WC-only execution found in 200 schedules"


# ---------------------------------------------------------------------------
# Sec 7: delta-admissible delay
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(p=st.integers(2, 4), n=st.integers(2, 4), seed=SEEDS,
       delta=st.integers(1, 2))
def test_delta_schedules_satisfy_async_constraints(p, n, seed, delta):
    h = random_schedule("dc", p, n, seed=seed, delta=delta)
    assert H.is_complete(h, p, n)
    assert H.satisfies_read_constraint(h, delta=delta)
    assert H.satisfies_write_constraint(h, p, delta=delta)


def test_delta_admits_non_sequential_histories():
    """delta > 0 must admit histories that the delta=0 engine rejects —
    the whole point of admissible delay."""
    found = 0
    for seed in range(300):
        h = random_schedule("dc", 3, 3, seed=seed, delta=2)
        if not H.is_sequentially_correct(h, 3):
            found += 1
    assert found > 0


def test_delta_zero_matches_bitvector_engine():
    """Sec 7.1 engine at delta=0 == Sec 5 bit-vector engine (same admitted
    histories for the same random choices)."""
    for seed in range(50):
        h1 = random_schedule("dc", 3, 3, seed=seed)          # bit-vector
        h2 = random_schedule("dc-array", 3, 3, seed=seed)    # delta array
        assert h1 == h2
