"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,KV,hd,bq,bk", [
        (64, 4, 4, 32, 32, 32),      # MHA
        (96, 4, 2, 32, 32, 32),      # GQA, ragged block tail
        (128, 6, 2, 16, 64, 32),     # GQA 3:1, mixed blocks
        (33, 2, 1, 8, 16, 16),       # non-multiple seq (padding path)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                               (False, 0)])
    def test_matches_ref(self, S, H, KV, hd, bq, bk, dtype, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _rand(ks[0], (2, S, H, hd), dtype)
        k = _rand(ks[1], (2, S, KV, hd), dtype)
        v = _rand(ks[2], (2, S, KV, hd), dtype)
        want = ref.attention(q, k, v, causal=causal, window=window)
        got = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_chunked_xla_path_matches(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = _rand(ks[0], (1, 4096, 2, 16), jnp.float32)
        k = _rand(ks[1], (1, 4096, 1, 16), jnp.float32)
        v = _rand(ks[2], (1, 4096, 1, 16), jnp.float32)
        want = ref.attention(q, k, v, causal=True, window=128)
        got = ref.attention_chunked(q, k, v, causal=True, window=128,
                                    block_q=512)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestRWKV6:
    @pytest.mark.parametrize("T,H,hd,bt", [
        (32, 2, 16, 16), (48, 4, 32, 16), (40, 1, 64, 32),  # ragged tail
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, T, H, hd, bt, dtype):
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        B = 2
        r = _rand(ks[0], (B, T, H, hd), dtype) * 0.5
        k = _rand(ks[1], (B, T, H, hd), dtype) * 0.5
        v = _rand(ks[2], (B, T, H, hd), dtype) * 0.5
        w = (jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd), jnp.float32))
             * 0.5 + 0.45).astype(dtype)
        u = _rand(ks[4], (H, hd), dtype) * 0.1
        want = ref.rwkv6(r, k, v, w, u)
        got = rwkv6_scan(r, k, v, w, u, block_t=bt, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_stateful_continuation(self):
        """Splitting a sequence across two stateful calls == one call."""
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        B, T, H, hd = 1, 24, 2, 16
        r = _rand(ks[0], (B, T, H, hd), jnp.float32) * 0.5
        k = _rand(ks[1], (B, T, H, hd), jnp.float32) * 0.5
        v = _rand(ks[2], (B, T, H, hd), jnp.float32) * 0.5
        w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd), jnp.float32)) * 0.5 + 0.4
        u = _rand(ks[4], (H, hd), jnp.float32) * 0.1
        full = ref.rwkv6(r, k, v, w, u)
        S0 = jnp.zeros((B, H, hd, hd))
        y1, S1 = ref.rwkv6_stateful(r[:, :10], k[:, :10], v[:, :10],
                                    w[:, :10], u, S0)
        y2, _ = ref.rwkv6_stateful(r[:, 10:], k[:, 10:], v[:, 10:],
                                   w[:, 10:], u, S1)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full,
                                   rtol=1e-5, atol=1e-6)


class TestRGLRU:
    @pytest.mark.parametrize("T,D,bd,bt", [
        (32, 64, 64, 16), (48, 160, 64, 32), (50, 96, 32, 16),  # ragged
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, T, D, bd, bt, dtype):
        ks = jax.random.split(jax.random.PRNGKey(4), 2)
        x = _rand(ks[0], (2, T, D), dtype)
        a = jax.nn.sigmoid(_rand(ks[1], (2, T, D), jnp.float32)).astype(dtype)
        want, _ = ref.rglru(x, a)
        got = rglru_scan(x, a, block_d=bd, block_t=bt, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_stateful_continuation(self):
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        x = _rand(ks[0], (1, 20, 32), jnp.float32)
        a = jax.nn.sigmoid(_rand(ks[1], (1, 20, 32), jnp.float32))
        full, hT = ref.rglru(x, a)
        y1, h1 = ref.rglru(x[:, :7], a[:, :7])
        y2, h2 = ref.rglru(x[:, 7:], a[:, 7:], h0=h1)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(h2, hT, rtol=1e-5, atol=1e-6)


class TestDecode:
    def test_attention_decode_matches_full(self):
        """Decode against a cache == last row of full attention."""
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        B, S, H, KV, hd = 2, 17, 4, 2, 16
        q = _rand(ks[0], (B, S, H, hd), jnp.float32)
        k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
        v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
        full = ref.attention(q, k, v, causal=True)
        got = ref.attention_decode(q[:, -1:], k, v,
                                   jnp.ones((S,), bool))
        np.testing.assert_allclose(got[:, 0], full[:, -1],
                                   rtol=1e-5, atol=1e-6)
