"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_matmul import moe_grouped_ffn
from repro.kernels.page_gather import page_gather
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ring_gather import ring_gather
from repro.kernels.rwkv6_scan import rwkv6_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,KV,hd,bq,bk", [
        (64, 4, 4, 32, 32, 32),      # MHA
        (96, 4, 2, 32, 32, 32),      # GQA, ragged block tail
        (128, 6, 2, 16, 64, 32),     # GQA 3:1, mixed blocks
        (33, 2, 1, 8, 16, 16),       # non-multiple seq (padding path)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                               (False, 0)])
    def test_matches_ref(self, S, H, KV, hd, bq, bk, dtype, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _rand(ks[0], (2, S, H, hd), dtype)
        k = _rand(ks[1], (2, S, KV, hd), dtype)
        v = _rand(ks[2], (2, S, KV, hd), dtype)
        want = ref.attention(q, k, v, causal=causal, window=window)
        got = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_chunked_xla_path_matches(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = _rand(ks[0], (1, 4096, 2, 16), jnp.float32)
        k = _rand(ks[1], (1, 4096, 1, 16), jnp.float32)
        v = _rand(ks[2], (1, 4096, 1, 16), jnp.float32)
        want = ref.attention(q, k, v, causal=True, window=128)
        got = ref.attention_chunked(q, k, v, causal=True, window=128,
                                    block_q=512)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestRWKV6:
    @pytest.mark.parametrize("T,H,hd,bt", [
        (32, 2, 16, 16), (48, 4, 32, 16), (40, 1, 64, 32),  # ragged tail
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, T, H, hd, bt, dtype):
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        B = 2
        r = _rand(ks[0], (B, T, H, hd), dtype) * 0.5
        k = _rand(ks[1], (B, T, H, hd), dtype) * 0.5
        v = _rand(ks[2], (B, T, H, hd), dtype) * 0.5
        w = (jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd), jnp.float32))
             * 0.5 + 0.45).astype(dtype)
        u = _rand(ks[4], (H, hd), dtype) * 0.1
        want = ref.rwkv6(r, k, v, w, u)
        got = rwkv6_scan(r, k, v, w, u, block_t=bt, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_stateful_continuation(self):
        """Splitting a sequence across two stateful calls == one call."""
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        B, T, H, hd = 1, 24, 2, 16
        r = _rand(ks[0], (B, T, H, hd), jnp.float32) * 0.5
        k = _rand(ks[1], (B, T, H, hd), jnp.float32) * 0.5
        v = _rand(ks[2], (B, T, H, hd), jnp.float32) * 0.5
        w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, hd), jnp.float32)) * 0.5 + 0.4
        u = _rand(ks[4], (H, hd), jnp.float32) * 0.1
        full = ref.rwkv6(r, k, v, w, u)
        S0 = jnp.zeros((B, H, hd, hd))
        y1, S1 = ref.rwkv6_stateful(r[:, :10], k[:, :10], v[:, :10],
                                    w[:, :10], u, S0)
        y2, _ = ref.rwkv6_stateful(r[:, 10:], k[:, 10:], v[:, 10:],
                                   w[:, 10:], u, S1)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full,
                                   rtol=1e-5, atol=1e-6)


class TestRGLRU:
    @pytest.mark.parametrize("T,D,bd,bt", [
        (32, 64, 64, 16), (48, 160, 64, 32), (50, 96, 32, 16),  # ragged
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, T, D, bd, bt, dtype):
        ks = jax.random.split(jax.random.PRNGKey(4), 2)
        x = _rand(ks[0], (2, T, D), dtype)
        a = jax.nn.sigmoid(_rand(ks[1], (2, T, D), jnp.float32)).astype(dtype)
        want, _ = ref.rglru(x, a)
        got = rglru_scan(x, a, block_d=bd, block_t=bt, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_stateful_continuation(self):
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        x = _rand(ks[0], (1, 20, 32), jnp.float32)
        a = jax.nn.sigmoid(_rand(ks[1], (1, 20, 32), jnp.float32))
        full, hT = ref.rglru(x, a)
        y1, h1 = ref.rglru(x[:, :7], a[:, :7])
        y2, h2 = ref.rglru(x[:, 7:], a[:, 7:], h0=h1)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(h2, hT, rtol=1e-5, atol=1e-6)


class TestDecodeAttentionFused:
    """Fused single-token GQA decode kernel vs ref.attention_decode."""

    @pytest.mark.parametrize("B,L,H,KV,hd,bk", [
        (2, 32, 4, 4, 16, 16),     # MHA
        (2, 40, 8, 2, 16, 16),     # GQA 4:1, ragged kv tail
        (1, 64, 6, 2, 32, 32),     # GQA 3:1
        (3, 17, 4, 1, 8, 8),       # MQA, non-multiple cache len
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, L, H, KV, hd, bk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(10), 3)
        q = _rand(ks[0], (B, 1, H, hd), dtype)
        k = _rand(ks[1], (B, L, KV, hd), dtype)
        v = _rand(ks[2], (B, L, KV, hd), dtype)
        # ring-style liveness: a hole plus a dead tail, as produced by the
        # slot = pos % L convention mid-generation
        valid = (jnp.arange(L) % 5 != 3) & (jnp.arange(L) < L - 2)
        want = ref.attention_decode(q, k, v, valid)
        got = decode_attention(q, k, v, valid, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_per_sequence_valid_rows(self):
        """(B, L) valid — continuous batching puts every sequence at its
        own position, so each batch row carries its own liveness mask."""
        ks = jax.random.split(jax.random.PRNGKey(15), 3)
        B, L, H, KV, hd = 3, 32, 4, 2, 16
        q = _rand(ks[0], (B, 1, H, hd), jnp.float32)
        k = _rand(ks[1], (B, L, KV, hd), jnp.float32)
        v = _rand(ks[2], (B, L, KV, hd), jnp.float32)
        # ring masks for pos = 0, 13, 45 (slot = pos % L, wrap-around row)
        pos = jnp.asarray([0, 13, 45])[:, None]
        idx = jnp.arange(L)[None, :]
        abs_pos = pos - jnp.mod(pos - idx, L)
        valid = (abs_pos >= 0) & (abs_pos >= pos - (L - 1))
        assert valid.shape == (B, L) and int(valid[0].sum()) == 1
        want = ref.attention_decode(q, k, v, valid)
        got = decode_attention(q, k, v, valid, block_k=16, interpret=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_single_live_slot(self):
        """pos=0: only slot 0 valid — blocks past it are fully dead and
        must not pollute the online softmax."""
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        B, L, H, KV, hd = 2, 48, 4, 2, 16
        q = _rand(ks[0], (B, 1, H, hd), jnp.float32)
        k = _rand(ks[1], (B, L, KV, hd), jnp.float32)
        v = _rand(ks[2], (B, L, KV, hd), jnp.float32)
        valid = jnp.arange(L) == 0
        want = ref.attention_decode(q, k, v, valid)
        got = decode_attention(q, k, v, valid, block_k=16, interpret=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        # only v[:, 0] should survive the softmax
        np.testing.assert_allclose(
            got[:, 0], ref._repeat_kv(v, H)[:, 0], rtol=2e-5, atol=2e-5)


class TestRingGatherKernel:
    """Scalar-prefetch row gather vs hist[idx] — must be bit-identical."""

    @pytest.mark.parametrize("size,N,block", [
        (1, 128, 128),             # delta=0 degenerate ring
        (4, 1024, 256),
        (4, 1000, 256),            # clipped trailing tile
        (3, 64, 128),              # single partial tile
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_bit_identical(self, size, N, block, dtype):
        hist = _rand(jax.random.PRNGKey(12), (size, N), dtype)
        for i in range(size):
            got = ring_gather(hist, jnp.asarray(i, jnp.int32), block=block,
                              interpret=True)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(hist[i]))

    def test_matches_ref_dispatch(self):
        hist = _rand(jax.random.PRNGKey(13), (5, 384), jnp.float32)
        idx = jnp.asarray(3, jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(ring_gather(hist, idx, interpret=True)),
            np.asarray(ref.ring_gather(hist, idx)))


class TestPageGatherKernel:
    """Scalar-prefetch page gather vs pool[page_table] — bit-identical."""

    @pytest.mark.parametrize("P,page,KV,hd,B,npp,block", [
        (9, 8, 2, 16, 2, 4, 1024),     # one tile per row
        (5, 4, 1, 8, 2, 2, 16),        # multi-tile rows
        (7, 8, 2, 8, 3, 2, 64),        # clipped trailing tile
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_bit_identical(self, P, page, KV, hd, B, npp, block, dtype):
        ks = jax.random.split(jax.random.PRNGKey(16), 2)
        pool = _rand(ks[0], (P, page, KV, hd), dtype)
        pt = jax.random.randint(ks[1], (B, npp), 0, P).astype(jnp.int32)
        got = page_gather(pool, pt, block=block, interpret=True)
        want = ref.page_gather(pool, pt)
        assert got.shape == (B, npp * page, KV, hd)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_shared_and_junk_pages(self):
        """Two sequences may map the same physical page (and idle slots
        all map the junk page) — the gather must not care."""
        pool = _rand(jax.random.PRNGKey(17), (4, 4, 1, 8), jnp.float32)
        pt = jnp.asarray([[2, 2], [3, 3]], jnp.int32)
        got = page_gather(pool, pt, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.page_gather(pool, pt)))


def _routing(key, G, g, E, C, k=2):
    """Top-k dispatch/combine tensors the way models/moe.py builds them."""
    probs = jax.nn.softmax(jax.random.normal(key, (G, g, E)))
    remaining = probs
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    dispatch = jnp.zeros((G, g, E, C), bool)
    fill = jnp.zeros((G, E), jnp.int32)
    for _ in range(k):
        gate, idx = jax.lax.top_k(remaining, 1)
        gate, idx = gate[..., 0], idx[..., 0]
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
        pos = fill[:, None, :] + (jnp.cumsum(onehot, axis=1)
                                  - onehot).astype(jnp.int32)
        keep = onehot.astype(bool) & (pos < C)
        slot = jax.nn.one_hot(jnp.where(keep, pos, C), C,
                              dtype=jnp.float32) * keep[..., None]
        dispatch |= slot.astype(bool)
        combine = combine + slot * gate[..., None, None]
        fill = fill + jnp.sum(onehot, axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)
    return dispatch, combine


class TestMoEGroupedKernel:
    """Grouped per-expert contraction vs the one-hot EGCd einsum path."""

    @pytest.mark.parametrize("G,g,E,C,d,f", [
        (1, 16, 4, 8, 32, 48),
        (2, 24, 4, 16, 16, 64),
        (1, 32, 8, 10, 64, 96),    # capacity drops (over-capacity tokens)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, G, g, E, C, d, f, dtype):
        ks = jax.random.split(jax.random.PRNGKey(14), 5)
        dispatch, combine = _routing(ks[0], G, g, E, C)
        xg = _rand(ks[1], (G, g, d), dtype)
        wg = _rand(ks[2], (E, d, f), dtype) * 0.1
        wu = _rand(ks[3], (E, d, f), dtype) * 0.1
        wd = _rand(ks[4], (E, f, d), dtype) * 0.1
        want = ref.moe_grouped_ffn(dispatch, combine, xg, wg, wu, wd)
        got = moe_grouped_ffn(dispatch, combine, xg, wg, wu, wd,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_moe_ffn_end_to_end(self, monkeypatch):
        """Full moe_ffn (router + capacity + aux) under both impls."""
        import dataclasses
        from repro.configs import get_smoke_config
        from repro.models import paramlib
        from repro.models.moe import moe_ffn, moe_specs
        cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"),
                                  dtype=jnp.float32, capacity_factor=4.0)
        params = paramlib.init_tree(moe_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.float32)
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        want, aux_want = moe_ffn(params, x, cfg)
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
        got, aux_got = moe_ffn(params, x, cfg)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(aux_got["lb_loss"]),
                                   float(aux_want["lb_loss"]), rtol=1e-6)


class TestDecode:
    def test_attention_decode_matches_full(self):
        """Decode against a cache == last row of full attention."""
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        B, S, H, KV, hd = 2, 17, 4, 2, 16
        q = _rand(ks[0], (B, S, H, hd), jnp.float32)
        k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
        v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
        full = ref.attention(q, k, v, causal=True)
        got = ref.attention_decode(q[:, -1:], k, v,
                                   jnp.ones((S,), bool))
        np.testing.assert_allclose(got[:, 0], full[:, -1],
                                   rtol=1e-5, atol=1e-6)


class TestPrefillPageAttention:
    """Chunked-prefill attention (context ring + in-chunk causal) vs the
    XLA reference, and both vs dense full-sequence attention."""

    @pytest.mark.parametrize("L,C,H,KV,hd,window,bk", [
        (32, 8, 4, 2, 16, 0, 16),     # GQA, full attn
        (16, 8, 2, 2, 8, 16, 8),      # MHA, windowed ring
        (24, 6, 4, 1, 16, 0, 128),    # ragged: one padded k block
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, L, C, H, KV, hd, window, bk, dtype):
        from repro.kernels.page_gather import prefill_page_attention
        ks = jax.random.split(jax.random.PRNGKey(21), 5)
        B, start = 2, 10
        q = _rand(ks[0], (B, C, H, hd), dtype)
        k_ctx = _rand(ks[1], (B, L, KV, hd), dtype)
        v_ctx = _rand(ks[2], (B, L, KV, hd), dtype)
        k_new = _rand(ks[3], (B, C, KV, hd), dtype)
        v_new = _rand(ks[4], (B, C, KV, hd), dtype)
        idx = jnp.arange(L, dtype=jnp.int32)
        last = start - 1
        abs_pos = last - jnp.mod(last - idx, L)      # ring reconstruction
        ctx_pos = jnp.broadcast_to(
            jnp.where(abs_pos >= 0, abs_pos, -1)[None], (B, L))
        q_pos = jnp.broadcast_to(
            (start + jnp.arange(C, dtype=jnp.int32))[None], (B, C))
        want = ref.prefill_page_attention(q, k_ctx, v_ctx, k_new, v_new,
                                          ctx_pos, q_pos, window=window)
        got = prefill_page_attention(q, k_ctx, v_ctx, k_new, v_new,
                                     ctx_pos, q_pos, window=window,
                                     block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_matches_dense_attention(self):
        """Context slots 0..start-1 + chunk == rows start..start+C-1 of
        one dense causal attention over the whole sequence."""
        ks = jax.random.split(jax.random.PRNGKey(22), 3)
        B, S, start, H, KV, hd = 1, 24, 16, 4, 2, 16
        C, L = S - start, 32
        q = _rand(ks[0], (B, S, H, hd), jnp.float32)
        k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
        v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
        full = ref.attention(q, k, v, causal=True)
        k_ctx = jnp.zeros((B, L, KV, hd)).at[:, :start].set(k[:, :start])
        v_ctx = jnp.zeros((B, L, KV, hd)).at[:, :start].set(v[:, :start])
        ctx_pos = jnp.where(jnp.arange(L) < start, jnp.arange(L), -1)[None]
        q_pos = (start + jnp.arange(C))[None].astype(jnp.int32)
        got = ref.prefill_page_attention(
            q[:, start:], k_ctx, v_ctx, k[:, start:], v[:, start:],
            ctx_pos.astype(jnp.int32), q_pos)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(full[:, start:]),
                                   rtol=1e-5, atol=1e-5)
