"""Atomic, shard-file checkpointing with elastic resharding.

Format: a checkpoint is a directory ``step_<N>/`` containing
  manifest.json       — pytree structure, per-leaf dtype/shape, shard counts
  <leaf_id>.s<k>.npy  — shard files (split along axis 0 when large)

Properties needed at scale, reproduced here faithfully at laptop scale:

  * **atomicity** — written to ``step_<N>.tmp`` then os.rename'd; a crash
    mid-write never corrupts the latest checkpoint (restart logic skips
    .tmp directories);
  * **elastic resharding** — leaves are stored as *logical* arrays split
    into content-defined shard files, so a checkpoint saved from any mesh
    loads onto any other mesh/worker count (the paper's repartitioning of
    the parameter database Pi when p changes);
  * **resume exactness** — optimizer state, step counter and data-stream
    position are all part of the tree; training continues bit-identically
    (asserted in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

_SHARD_BYTES = 64 * 1024 * 1024   # split leaves larger than this


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _path_str(path) -> str:
    out = []
    for p in path:
        key = getattr(p, "key", None)
        if key is None:
            key = getattr(p, "idx", str(p))
        out.append(str(key))
    return "/".join(out)


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Atomically write ``tree`` under ``ckpt_dir/step_<step>``."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V":
            # ml_dtypes customs (bfloat16, float8_*) don't survive np.save;
            # store a same-width unsigned view, restore from the manifest
            arr = arr.view({1: np.uint8, 2: np.uint16,
                            4: np.uint32}[arr.dtype.itemsize])
        n_shards = max(1, -(-arr.nbytes // _SHARD_BYTES))
        n_shards = min(n_shards, max(arr.shape[0], 1)) if arr.ndim else 1
        manifest["leaves"].append({
            "id": i, "path": _path_str(path), "dtype": logical_dtype,
            "shape": list(arr.shape), "n_shards": int(n_shards)})
        if n_shards == 1:
            np.save(os.path.join(tmp, f"{i}.s0.npy"), arr)
        else:
            for k, part in enumerate(np.array_split(arr, n_shards, axis=0)):
                np.save(os.path.join(tmp, f"{i}.s{k}.npy"), part)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, like_tree):
    """Load into the structure of ``like_tree`` (host numpy arrays)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {m["path"]: m for m in manifest["leaves"]}

    leaves, treedef = _leaf_paths(like_tree)
    out = []
    for path, leaf in leaves:
        m = by_path[_path_str(path)]
        parts = [np.load(os.path.join(d, f"{m['id']}.s{k}.npy"))
                 for k in range(m["n_shards"])]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if arr.dtype.kind in ("u", "V") and str(arr.dtype) != m["dtype"]:
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, m["dtype"], None)
                           or np.dtype(m["dtype"]))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {m['path']}: ckpt {arr.shape} vs "
                f"expected {np.shape(leaf)}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_onto_mesh(ckpt_dir: str, step: int, like_tree, shardings):
    """Elastic restore: load logical arrays and place them under the target
    shardings (any mesh shape — the repartition of Pi)."""
    host = load_checkpoint(ckpt_dir, step, like_tree)
    return jax.tree.map(
        lambda arr, sh, like: jax.device_put(
            np.asarray(arr, dtype=like.dtype), sh),
        host, shardings, like_tree)
