"""Fault-tolerant checkpointing with cross-mesh resharding."""
from .checkpoint import (latest_step, load_checkpoint, restore_onto_mesh,  # noqa: F401
                         save_checkpoint)
