"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` returns the reduced same-family variant used
by the CPU smoke tests (small widths/depths, tiny vocab — same code paths).
"""
from __future__ import annotations

import importlib

ARCHS = (
    "llama3_2_1b",
    "smollm_360m",
    "olmo_1b",
    "gemma3_4b",
    "musicgen_large",
    "mixtral_8x7b",
    "llama4_scout_17b_16e",
    "rwkv6_1_6b",
    "llama3_2_vision_11b",
    "recurrentgemma_2b",
)

# public --arch ids (hyphenated) -> module names
ALIASES = {
    "llama3.2-1b": "llama3_2_1b",
    "smollm-360m": "smollm_360m",
    "olmo-1b": "olmo_1b",
    "gemma3-4b": "gemma3_4b",
    "musicgen-large": "musicgen_large",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str):
    return _module(arch).config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()


def all_arch_ids() -> list[str]:
    return list(ALIASES.keys())
