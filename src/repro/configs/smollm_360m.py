"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        groups=(BlockGroup(("attn",), 32),),
        d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
        vocab_size=49152, head_dim=64, rope_theta=10_000.0,
        norm="rmsnorm", mlp="swiglu", tie_embeddings=True,
        max_seq=32_768, source="hf:HuggingFaceTB/SmolLM-360M")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(("attn",), 2),),
        d_model=60, n_heads=3, n_kv_heads=1, d_ff=96, head_dim=20,
        vocab_size=256, max_seq=128)
