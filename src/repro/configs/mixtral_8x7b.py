"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]

EP note: 8 experts do not divide the 16-way model axis, so experts stay
replicated across `model` and each expert's d_ff tensor-shards (DESIGN.md
§4); llama4-scout exercises the true expert-parallel path."""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        groups=(BlockGroup(("swa",), 32),),
        d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=32000, head_dim=128, window=4096,
        rope_theta=1_000_000.0, norm="rmsnorm", mlp="swiglu",
        tie_embeddings=False,
        n_experts=8, top_k=2, capacity_factor=1.25,
        max_seq=32_768, long_context=True,     # SWA bounds the KV cache
        source="arXiv:2401.04088")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(("swa",), 2),),
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, head_dim=16,
        vocab_size=256, window=16, n_experts=4, top_k=2,
        moe_group_size=64, max_seq=128)
