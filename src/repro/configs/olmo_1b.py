"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm.  [arXiv:2402.00838; hf]"""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        groups=(BlockGroup(("attn",), 16),),
        d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
        vocab_size=50304, rope_theta=10_000.0,
        norm="layernorm_np",            # the OLMo signature choice
        mlp="swiglu", tie_embeddings=True,
        max_seq=4096, source="arXiv:2402.00838")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(("attn",), 2),),
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, max_seq=128)
