"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention, 1 attention : 2 recurrent.
[arXiv:2402.19427; hf]"""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig

_PAT = ("rglru", "rglru", "local")


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        # 26 layers = 8 x (rglru, rglru, local) + 2 rglru tail
        groups=(BlockGroup(_PAT, 8), BlockGroup(("rglru", "rglru"), 1)),
        d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
        vocab_size=256000, head_dim=256, window=2048,
        rope_theta=10_000.0, norm="rmsnorm", mlp="geglu",
        tie_embeddings=True, embed_scale=True,
        d_rnn=2560, conv_width=4,
        max_seq=1_048_576, source="arXiv:2402.19427")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(_PAT, 1),),
        d_model=64, n_heads=4, n_kv_heads=1, d_ff=96, head_dim=16,
        vocab_size=256, window=16, d_rnn=64, max_seq=128)
