"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536.  Finch — data-dependent decay.  [arXiv:2404.05892; unverified]"""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        groups=(BlockGroup(("rwkv6",), 24),),
        d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
        vocab_size=65536, head_dim=64, decay_lora=64,
        norm="layernorm", tie_embeddings=False,
        max_seq=1_048_576,              # O(1) state: unbounded context
        source="arXiv:2404.05892")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(("rwkv6",), 2),),
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, head_dim=16,
        vocab_size=256, decay_lora=8, max_seq=128)
