"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-1B; unverified]"""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        groups=(BlockGroup(("attn",), 16),),
        d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
        vocab_size=128256, head_dim=64, rope_theta=500_000.0,
        norm="rmsnorm", mlp="swiglu", tie_embeddings=True,
        max_seq=131_072, source="hf:meta-llama/Llama-3.2-1B")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(("attn",), 2),),
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
        vocab_size=256, max_seq=128)
