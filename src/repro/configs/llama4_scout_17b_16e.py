"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

16 experts shard 1:1 over the 16-way model axis (true expert parallelism).
Full attention per the assignment note -> long_500k skipped (DESIGN.md §5)."""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        groups=(BlockGroup(("attn",), 48),),
        d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab_size=202048, head_dim=128, rope_theta=500_000.0,
        norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
        n_experts=16, top_k=1, capacity_factor=1.25,
        max_seq=131_072, source="hf:meta-llama/Llama-4-Scout-17B-16E")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(("attn",), 2),),
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, head_dim=16,
        vocab_size=256, n_experts=4, top_k=1, moe_group_size=64,
        max_seq=128)
