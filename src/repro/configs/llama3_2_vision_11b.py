"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  Cross-attention image layers (every 5th layer).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Frontend stub: the ViT tower is out of scope — input_specs() provides
precomputed patch embeddings (B, 1600, 1280); the model owns the projection
into d_model and the gated cross-attention layers."""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig

_PAT = ("attn", "attn", "attn", "attn", "xattn")


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        groups=(BlockGroup(_PAT, 8),),   # 40 layers, xattn every 5th
        d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=128256, head_dim=128, rope_theta=500_000.0,
        norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
        frontend="vision", n_frontend_tokens=1600, d_frontend=1280,
        max_seq=131_072, source="hf:meta-llama/Llama-3.2-11B-Vision")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(_PAT, 1),),
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, head_dim=16,
        vocab_size=256, n_frontend_tokens=8, d_frontend=24, max_seq=128)
