"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048.  Decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Frontend stub: the EnCodec tokenizer is out of scope — input_specs()
provides precomputed codebook token ids (single interleaved stream,
vocab 2048), per the assignment's backbone-only rule."""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        groups=(BlockGroup(("attn",), 48),),
        d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab_size=2048, rope_theta=10_000.0,
        norm="layernorm", mlp="gelu", tie_embeddings=False,
        frontend="audio_tokens",
        max_seq=32_768, source="arXiv:2306.05284")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(("attn",), 2),),
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=128, max_seq=128)
