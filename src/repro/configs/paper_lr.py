"""The paper's own experimental config (Sec 6): feature-partitioned linear
regression — synthetic (960 features x 5000 examples) and the real-dataset
shape (150,360 features x 16,087 examples; Kogan et al. 2009 proxy)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperLRConfig:
    n_features: int = 960
    n_examples: int = 5000
    lr: float = 0.05
    n_iters: int = 100
    mode: str = "gd"            # gd | sgd | minibatch
    batch_size: int = 100
    seed: int = 0


def synthetic() -> PaperLRConfig:
    return PaperLRConfig()


def real_shape() -> PaperLRConfig:
    """The Kogan et al. dataset is not redistributable; we reproduce its
    SHAPE with a sparse synthetic equivalent (documented in DESIGN.md)."""
    return PaperLRConfig(n_features=150_360, n_examples=16_087,
                         mode="sgd", n_iters=400)
