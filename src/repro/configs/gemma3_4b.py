"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144.  5:1 local:global attention, 128k context, qk-norm,
distinct RoPE theta for local (10k) vs global (1M) layers.
[hf:google/gemma-3-1b-pt; unverified]

Long-context note: global layers keep a full KV cache, but decode memory is
bounded after kv_seq sequence-parallel sharding — long_500k is exercised
(DESIGN.md §5)."""
import dataclasses
from repro.models.config import BlockGroup, ModelConfig

_PAT = ("local", "local", "local", "local", "local", "attn")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        # 34 layers = 5 x (5 local + 1 global) + 4 local tail
        groups=(BlockGroup(_PAT, 5), BlockGroup(("local",) * 4, 1)),
        d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
        vocab_size=262144, head_dim=256, window=1024,
        rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        norm="rmsnorm", qk_norm=True, mlp="geglu",
        tie_embeddings=True, embed_scale=True,
        max_seq=131_072, long_context=True,
        source="hf:google/gemma-3-4b-pt")


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), groups=(BlockGroup(_PAT, 1),),
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
        vocab_size=256, window=16, max_seq=128)
