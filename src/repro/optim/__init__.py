"""Optimizers + schedules in pure JAX."""
from .optimizers import (OptConfig, Optimizer, clip_by_global_norm,  # noqa: F401
                         compress_grads, global_norm, init_residual,
                         make_optimizer)
from . import schedules  # noqa: F401
