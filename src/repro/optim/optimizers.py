"""Optimizers in pure JAX (no optax dependency).

AdamW with fp32 master params + bf16 compute cast, SGD/momentum, global-norm
clipping, and the int8 gradient-compression transform (error feedback) used
as a distributed-optimization trick: gradients are quantized before the
cross-replica reduction, halving (vs bf16) or quartering (vs f32) the
collective bytes the paper's "write" step costs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | sgd | momentum
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    grad_clip: float = 1.0         # global-norm clip; 0 disables
    compression: str = "none"      # none | int8


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    # update(grads, opt_state, params) -> (new_params, new_opt_state)


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127) \
        .astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: PyTree, residual: PyTree
                   ) -> tuple[PyTree, PyTree]:
    """Error-feedback int8 compression: quantize (grad + residual); the
    quantization error is carried to the next step so the *accumulated*
    gradient signal is unbiased (1-bit-Adam-style memory compensation)."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), target - deq
    pairs = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_res


def init_residual(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def make_optimizer(cfg: OptConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _adamw(cfg)
    if cfg.name == "sgd":
        return _sgd(cfg, momentum=0.0)
    if cfg.name == "momentum":
        return _sgd(cfg, momentum=cfg.momentum)
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def _adamw(cfg: OptConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        state = {"m": jax.tree.map(zeros, params),
                 "v": jax.tree.map(zeros, params),
                 "step": jnp.zeros((), jnp.int32)}
        if cfg.compression == "int8":
            state["residual"] = init_residual(params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        if cfg.compression == "int8":
            grads, new_residual = compress_grads(grads, state["residual"])
        if cfg.grad_clip > 0:
            grads = clip_by_global_norm(grads, cfg.grad_clip)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * gf
            v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
            mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
            vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
            pf = p.astype(jnp.float32)
            new_p = pf - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                   + cfg.weight_decay * pf)
            return new_p.astype(p.dtype), m, v

        triples = jax.tree.map(upd, grads, state["m"], state["v"], params)
        unzip = lambda i: jax.tree.map(lambda t: t[i], triples,  # noqa: E731
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_params, new_m, new_v = unzip(0), unzip(1), unzip(2)
        new_state = {"m": new_m, "v": new_v, "step": step}
        if cfg.compression == "int8":
            new_state["residual"] = new_residual
        return new_params, new_state

    return Optimizer(init, update)


def _sgd(cfg: OptConfig, momentum: float) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum > 0:
            state["mom"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if cfg.compression == "int8":
            state["residual"] = init_residual(params)
        return state

    def update(grads, state, params):
        new_state = {"step": state["step"] + 1}
        if cfg.compression == "int8":
            grads, new_state["residual"] = \
                compress_grads(grads, state["residual"])
        if cfg.grad_clip > 0:
            grads = clip_by_global_norm(grads, cfg.grad_clip)
        if momentum > 0:
            new_mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            new_params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32)
                              - cfg.lr * m).astype(p.dtype),
                params, new_mom)
            new_state["mom"] = new_mom
        else:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - cfg.lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
        return new_params, new_state

    return Optimizer(init, update)
