"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup_cosine(lr: float, warmup: int, total: int,
                         final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = lr * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, lr * cos)
    return f


def inverse_sqrt(lr: float, warmup: int):
    def f(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return lr * jnp.minimum(s / warmup, jnp.sqrt(warmup / s))
    return f
