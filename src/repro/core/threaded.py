"""Live multi-threaded parameter-database runtime (paper Sec 6).

Real Python threads train a feature-partitioned linear-regression model
(the paper's prototype task) against a blocking parameter store that
enforces either the BSP barriers (Algorithm 2a) or the data-centric RC/WC
constraints (Algorithm 2b / Sec-7.1 protocol).

Correctness property (the paper's central claim): with ``delta=0`` the final
parameter vector is **bit-identical** to single-threaded sequential
execution, for GD, SGD and mini-batch — regardless of thread interleaving.
This holds because each worker's chunk update is a deterministic function of
the full-theta snapshot it read (whose value RC/WC pins to exactly the
previous iteration's writes) and a shared, pre-drawn sample schedule.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Literal

import numpy as np

from .history import Op, READ, WRITE


@dataclasses.dataclass(frozen=True)
class LRTask:
    """A linear-regression training task (the paper's Sec-6 workload)."""
    X: np.ndarray            # (n_examples, n_features)
    y: np.ndarray            # (n_examples,)
    lr: float = 0.05
    n_iters: int = 30
    mode: Literal["gd", "sgd", "minibatch"] = "gd"
    batch_size: int = 100
    seed: int = 0

    def sample_schedule(self) -> np.ndarray | None:
        """Pre-draw the SGD/mini-batch sample indices per iteration so every
        execution (sequential or parallel, any policy) sees the same data
        order — required for the bit-identical guarantee."""
        n = self.X.shape[0]
        rng = np.random.default_rng(self.seed)
        if self.mode == "sgd":
            return rng.integers(0, n, size=(self.n_iters, 1))
        if self.mode == "minibatch":
            return rng.integers(0, n, size=(self.n_iters, self.batch_size))
        return None


def make_synthetic_lr(n_examples: int, n_features: int,
                      seed: int = 0, noise: float = 0.01) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic dataset in the style of Sec 6.1 (960 features, 5000 rows)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n_examples, n_features)) / np.sqrt(n_features)
    w_true = rng.normal(size=n_features)
    y = X @ w_true + noise * rng.normal(size=n_examples)
    return X, y


def chunk_slices(n_features: int, n_workers: int) -> list[slice]:
    bounds = np.linspace(0, n_features, n_workers + 1).astype(int)
    return [slice(int(a), int(b)) for a, b in zip(bounds[:-1], bounds[1:])]


def _chunk_update(task: LRTask, theta: np.ndarray, sl: slice, itr: int,
                  schedule: np.ndarray | None) -> np.ndarray:
    """New value for one feature chunk given a full-theta snapshot.
    Deterministic in (theta, itr) — the f_i of Equation 1."""
    X, y = task.X, task.y
    if task.mode == "gd":
        resid = X @ theta - y
        g = X[:, sl].T @ resid / X.shape[0]
    else:
        idx = schedule[itr - 1]
        Xb = X[idx]
        resid = Xb @ theta - y[idx]
        g = Xb[:, sl].T @ resid / len(idx)
    return theta[sl] - task.lr * g


def run_sequential(task: LRTask, n_workers: int) -> np.ndarray:
    """Algorithm 1: the single-threaded ground truth (same chunking)."""
    slices = chunk_slices(task.X.shape[1], n_workers)
    schedule = task.sample_schedule()
    theta = np.zeros(task.X.shape[1])
    for itr in range(1, task.n_iters + 1):
        snap = theta.copy()          # all reads precede all writes
        news = [_chunk_update(task, snap, sl, itr, schedule) for sl in slices]
        for sl, v in zip(slices, news):
            theta[sl] = v
    return theta


# ---------------------------------------------------------------------------
# Blocking parameter stores
# ---------------------------------------------------------------------------

class RCWCStore:
    """The Sec-5 / Sec-7.1 protocol as a blocking store.

    read(worker, chunk, itr)  blocks until version[chunk] >= itr - 1 - delta
    write(worker, chunk, itr) blocks until min_k last_read[chunk][k] >= itr - delta
    """

    def __init__(self, init_chunks: list[np.ndarray], n_workers: int,
                 delta: int = 0, record: bool = False):
        self.chunks = [c.copy() for c in init_chunks]
        self.version = [0] * len(init_chunks)
        self.last_read = [[0] * n_workers for _ in init_chunks]
        self.delta = delta
        self.cond = threading.Condition()
        self.history: list[Op] | None = [] if record else None

    def read(self, worker: int, chunk: int, itr: int) -> np.ndarray:
        with self.cond:
            self.cond.wait_for(
                lambda: self.version[chunk] >= itr - 1 - self.delta)
            val = self.chunks[chunk].copy()
            self.last_read[chunk][worker] = itr
            if self.history is not None:
                self.history.append(Op(READ, worker, chunk, itr))
            self.cond.notify_all()
            return val

    def write(self, worker: int, chunk: int, itr: int, value: np.ndarray) -> None:
        with self.cond:
            self.cond.wait_for(
                lambda: min(self.last_read[chunk]) >= itr - self.delta)
            self.chunks[chunk] = value
            self.version[chunk] = itr
            if self.history is not None:
                self.history.append(Op(WRITE, worker, chunk, itr))
            self.cond.notify_all()


class BSPStore:
    """Algorithm 2a: read barrier + write barrier around a plain store."""

    def __init__(self, init_chunks: list[np.ndarray], n_workers: int,
                 record: bool = False):
        self.chunks = [c.copy() for c in init_chunks]
        self.read_barrier = threading.Barrier(n_workers)
        self.write_barrier = threading.Barrier(n_workers)
        self.lock = threading.Lock()
        self.history: list[Op] | None = [] if record else None

    def read_all(self, worker: int, itr: int) -> list[np.ndarray]:
        self.read_barrier.wait()     # wait for all writes of itr-1
        with self.lock:
            vals = [c.copy() for c in self.chunks]
            if self.history is not None:
                for j in range(len(self.chunks)):
                    self.history.append(Op(READ, worker, j, itr))
        return vals

    def write(self, worker: int, chunk: int, itr: int, value: np.ndarray) -> None:
        self.write_barrier.wait()    # wait for all reads of itr
        with self.lock:
            self.chunks[chunk] = value
            if self.history is not None:
                self.history.append(Op(WRITE, worker, chunk, itr))


# ---------------------------------------------------------------------------
# Parallel runners
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunStats:
    theta: np.ndarray
    wall_time: float
    history: list[Op] | None


def run_parallel(task: LRTask, n_workers: int, policy: str = "dc",
                 delta: int = 0, record_history: bool = False) -> RunStats:
    """Train with ``n_workers`` real threads under the given policy."""
    d = task.X.shape[1]
    slices = chunk_slices(d, n_workers)
    schedule = task.sample_schedule()
    init = [np.zeros(sl.stop - sl.start) for sl in slices]

    if policy == "bsp":
        store: RCWCStore | BSPStore = BSPStore(init, n_workers, record_history)
    elif policy == "dc":
        store = RCWCStore(init, n_workers, delta, record_history)
    else:
        raise ValueError(f"unknown policy {policy!r}")

    errors: list[BaseException] = []

    def worker(i: int) -> None:
        try:
            for itr in range(1, task.n_iters + 1):
                if policy == "bsp":
                    vals = store.read_all(i, itr)          # type: ignore[union-attr]
                else:
                    vals = [store.read(i, j, itr)          # type: ignore[union-attr]
                            for j in range(n_workers)]
                theta = np.concatenate(vals)
                new = _chunk_update(task, theta, slices[i], itr, schedule)
                store.write(i, i, itr, new)
        except BaseException as e:  # surface thread failures to the caller
            errors.append(e)
            raise

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(n_workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    if any(t.is_alive() for t in threads):
        raise RuntimeError("worker threads did not terminate (deadlock?)")
    theta = np.concatenate([c for c in store.chunks])
    return RunStats(theta, wall, store.history)


def loss(task: LRTask, theta: np.ndarray) -> float:
    r = task.X @ theta - task.y
    return float(0.5 * np.mean(r * r))
