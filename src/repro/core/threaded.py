"""Live multi-threaded parameter-database runtime (paper Sec 6).

Real Python threads train a feature-partitioned linear-regression model
(the paper's prototype task) against the blocking ParameterDB backend
(:class:`repro.pdb.ThreadedParameterDB`), under any consistency policy:
BSP barriers (Algorithm 2a), data-centric RC/WC constraints (Algorithm 2b /
Sec-7.1 protocol, exact or delta-relaxed), SSP per-worker clocks, or
unsynchronized Hogwild.

Correctness property (the paper's central claim): with ``delta=0`` the final
parameter vector is **bit-identical** to single-threaded sequential
execution, for GD, SGD and mini-batch — regardless of thread interleaving.
This holds because each worker's chunk update is a deterministic function of
the full-theta snapshot it read (whose value RC/WC pins to exactly the
previous iteration's writes) and a shared, pre-drawn sample schedule.

The blocking/wait-condition machinery lives entirely in
:mod:`repro.pdb.db`; this module only provides the Sec-6 workload and the
thread harness.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Literal

import numpy as np

from ..pdb import ThreadedParameterDB, make_policy
from .history import Op


@dataclasses.dataclass(frozen=True)
class LRTask:
    """A linear-regression training task (the paper's Sec-6 workload)."""
    X: np.ndarray            # (n_examples, n_features)
    y: np.ndarray            # (n_examples,)
    lr: float = 0.05
    n_iters: int = 30
    mode: Literal["gd", "sgd", "minibatch"] = "gd"
    batch_size: int = 100
    seed: int = 0

    def sample_schedule(self) -> np.ndarray | None:
        """Pre-draw the SGD/mini-batch sample indices per iteration so every
        execution (sequential or parallel, any policy) sees the same data
        order — required for the bit-identical guarantee."""
        n = self.X.shape[0]
        rng = np.random.default_rng(self.seed)
        if self.mode == "sgd":
            return rng.integers(0, n, size=(self.n_iters, 1))
        if self.mode == "minibatch":
            return rng.integers(0, n, size=(self.n_iters, self.batch_size))
        return None


def make_synthetic_lr(n_examples: int, n_features: int,
                      seed: int = 0, noise: float = 0.01) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic dataset in the style of Sec 6.1 (960 features, 5000 rows)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n_examples, n_features)) / np.sqrt(n_features)
    w_true = rng.normal(size=n_features)
    y = X @ w_true + noise * rng.normal(size=n_examples)
    return X, y


def chunk_slices(n_features: int, n_workers: int) -> list[slice]:
    bounds = np.linspace(0, n_features, n_workers + 1).astype(int)
    return [slice(int(a), int(b)) for a, b in zip(bounds[:-1], bounds[1:])]


def chunk_update(task: LRTask, theta: np.ndarray, sl: slice, itr: int,
                 schedule: np.ndarray | None) -> np.ndarray:
    """New value for one feature chunk given a full-theta snapshot.
    Deterministic in (theta, itr) — the f_i of Equation 1."""
    X, y = task.X, task.y
    if task.mode == "gd":
        resid = X @ theta - y
        g = X[:, sl].T @ resid / X.shape[0]
    else:
        idx = schedule[itr - 1]
        Xb = X[idx]
        resid = Xb @ theta - y[idx]
        g = Xb[:, sl].T @ resid / len(idx)
    return theta[sl] - task.lr * g


def run_sequential(task: LRTask, n_workers: int) -> np.ndarray:
    """Algorithm 1: the single-threaded ground truth (same chunking)."""
    slices = chunk_slices(task.X.shape[1], n_workers)
    schedule = task.sample_schedule()
    theta = np.zeros(task.X.shape[1])
    for itr in range(1, task.n_iters + 1):
        snap = theta.copy()          # all reads precede all writes
        news = [chunk_update(task, snap, sl, itr, schedule) for sl in slices]
        for sl, v in zip(slices, news):
            theta[sl] = v
    return theta


# ---------------------------------------------------------------------------
# Parallel runners
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunStats:
    theta: np.ndarray
    wall_time: float
    history: list[Op] | None
    staleness: dict | None = None


def run_parallel(task: LRTask, n_workers: int, policy: str = "dc",
                 delta: float = 0, record_history: bool = False,
                 timeout: float | None = 300.0) -> RunStats:
    """Train with ``n_workers`` real threads under the given policy
    ("bsp" | "dc" | "dc-array" | "ssp" | "hogwild").  ``timeout`` bounds
    each blocked DB op (None blocks forever)."""
    d = task.X.shape[1]
    slices = chunk_slices(d, n_workers)
    schedule = task.sample_schedule()
    init = [np.zeros(sl.stop - sl.start) for sl in slices]

    db = ThreadedParameterDB(
        init, n_workers,
        policy=make_policy(policy, n_workers, delta, n_chunks=n_workers),
        record=record_history, timeout=timeout)

    errors: list[BaseException] = []

    def worker(i: int) -> None:
        try:
            for itr in range(1, task.n_iters + 1):
                vals = db.read_all(i, itr)
                theta = np.concatenate(vals)
                new = chunk_update(task, theta, slices[i], itr, schedule)
                db.write(i, i, itr, new)
        except BaseException as e:  # surface thread failures to the caller
            errors.append(e)
            raise

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(n_workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=timeout)
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    if any(t.is_alive() for t in threads):
        raise RuntimeError("worker threads did not terminate (deadlock?)")
    return RunStats(db.theta(), wall, db.history, db.telemetry.summary())


def loss(task: LRTask, theta: np.ndarray) -> float:
    r = task.X @ theta - task.y
    return float(0.5 * np.mean(r * r))
