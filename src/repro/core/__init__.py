"""Core: the paper's parameter-database synchronization framework.

  history    — formal operation-history model + Theorem 1-3 checkers
  scheduler  — shim over repro.pdb.policies (Sec-5 / Sec-7.1 / BSP / SSP)
  simulator  — discrete-event makespan simulation (Fig 2 reproduction)
  threaded   — live multi-threaded linear-regression runtime (Sec 6) over
               the blocking ParameterDB backend
  staleness  — shim over repro.pdb.jax_backend (delta-staleness ring buffer)
  sync_jax   — sync-mode -> sharding-rule mapping for SPMD training

The unified consistency layer itself lives in :mod:`repro.pdb`.
"""
from . import history, scheduler, simulator, sync_jax, threaded  # noqa: F401
from .sync_jax import SyncConfig  # noqa: F401
