"""Core: the paper's parameter-database synchronization framework.

  history    — formal operation-history model + Theorem 1-3 checkers
  scheduler  — Sec-5 bit-vector / Sec-7.1 delta protocols + BSP baseline
  simulator  — discrete-event makespan simulation (Fig 2 reproduction)
  threaded   — live multi-threaded linear-regression runtime (Sec 6)
  staleness  — deterministic delta-staleness engine for JAX training
  sync_jax   — sync-mode -> sharding-rule mapping for SPMD training
"""
from . import history, scheduler, simulator, sync_jax, threaded  # noqa: F401
from .sync_jax import SyncConfig  # noqa: F401
