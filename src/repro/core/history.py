"""Formal model of parameter-database executions (paper Secs 3-4, 9).

An *operation* is ``r_i[pi_j][alpha]`` or ``w_i[pi_i][alpha]`` — worker ``i``
reading partition ``j`` (or writing its own partition) during iteration
``alpha``.  A *history* is a total order of operations.  This module provides

  * predicate checkers for the paper's barrier constraints (BSP, Sec 4.3),
    the relaxed read/write constraints (RC/WC, Sec 4.4) and their
    delta-admissible-delay forms (Sec 7);
  * the Definition-4 sequential-ML-computation checker (global form) and the
    per-partition correctness conditions used in the proof of Theorem 5;
  * a small interpreter that *executes* a history against a numeric
    fixed-point computation and compares the outcome with sequential
    execution — the semantic (not just syntactic) correctness check.

Iterations are 1-based, matching the paper's examples (Figs 1 and 3).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Sequence

import numpy as np

READ = "r"
WRITE = "w"


@dataclasses.dataclass(frozen=True, order=True)
class Op:
    """One database access: ``kind`` in {'r','w'}, by ``worker`` on
    partition ``chunk`` during iteration ``itr``."""

    kind: str
    worker: int
    chunk: int
    itr: int

    def __post_init__(self):
        if self.kind not in (READ, WRITE):
            raise ValueError(f"bad op kind {self.kind!r}")

    def __repr__(self) -> str:  # matches the paper's notation
        return f"{self.kind}{self.worker}[pi{self.chunk}][{self.itr}]"


History = Sequence[Op]


def r(worker: int, chunk: int, itr: int) -> Op:
    return Op(READ, worker, chunk, itr)


def w(worker: int, chunk: int, itr: int) -> Op:
    return Op(WRITE, worker, chunk, itr)


# ---------------------------------------------------------------------------
# History generators
# ---------------------------------------------------------------------------

def worker_program(worker: int, n_chunks: int, n_iters: int) -> list[list[Op]]:
    """The per-iteration op sequence each worker must issue (Def 3): read all
    partitions, then write its own.  Returns ``[ops_of_iter_1, ...]``."""
    out = []
    for a in range(1, n_iters + 1):
        ops = [r(worker, j, a) for j in range(n_chunks)]
        ops.append(w(worker, worker, a))
        out.append(ops)
    return out


def sequential_history(n_workers: int, n_iters: int) -> list[Op]:
    """The single-threaded ground-truth execution (Algorithm 1 / SEQ_1)."""
    h: list[Op] = []
    for a in range(1, n_iters + 1):
        for j in range(n_workers):
            h.append(r(0, j, a))  # single thread: worker id irrelevant
        for j in range(n_workers):
            h.append(Op(WRITE, j, j, a))
    return h


def bsp_history(n_workers: int, n_iters: int,
                read_perm: Sequence[int] | None = None,
                write_perm: Sequence[int] | None = None) -> list[Op]:
    """A canonical bulk-synchronous execution (Algorithm 2a): all reads of an
    iteration (in any order), then all writes (in any order)."""
    h: list[Op] = []
    workers = list(range(n_workers))
    for a in range(1, n_iters + 1):
        rp = list(read_perm) if read_perm is not None else workers
        wp = list(write_perm) if write_perm is not None else workers
        for i in rp:
            for j in range(n_workers):
                h.append(r(i, j, a))
        for i in wp:
            h.append(w(i, i, a))
    return h


def is_complete(h: History, n_workers: int, n_iters: int) -> bool:
    """Every worker performed its full Def-3 program exactly once."""
    need = set()
    for i in range(n_workers):
        for a in range(1, n_iters + 1):
            for j in range(n_workers):
                need.add(Op(READ, i, j, a))
            need.add(Op(WRITE, i, i, a))
    return set(h) == need and len(h) == len(need)


# ---------------------------------------------------------------------------
# Constraint predicates (Secs 4.3, 4.4, 7)
# ---------------------------------------------------------------------------

def _positions(h: History) -> dict[Op, int]:
    return {op: idx for idx, op in enumerate(h)}


def satisfies_read_constraint(h: History, delta: int = 0) -> bool:
    """RC (delta=0):  w_j[pi_j][alpha] < r_i[pi_j][alpha+1].
    Async RC (Sec 7): w_j[pi_j][alpha-1-delta] < r_i[pi_j][alpha]."""
    pos = _positions(h)
    for op in h:
        if op.kind != READ:
            continue
        want = op.itr - 1 - delta
        if want < 1:
            continue  # initial values suffice
        dep = Op(WRITE, op.chunk, op.chunk, want)
        if dep in pos and pos[dep] > pos[op]:
            return False
        # in a complete history the dependency write must exist
        if dep not in pos and any(o.kind == WRITE and o.chunk == op.chunk
                                  and o.itr == want for o in h):
            return False
    return True


def satisfies_write_constraint(h: History, n_workers: int,
                               delta: int = 0) -> bool:
    """WC (delta=0):  r_j[pi_i][alpha] < w_i[pi_i][alpha]  for every j.
    Async WC (Sec 7): r_j[pi_i][alpha-delta] < w_i[pi_i][alpha]."""
    pos = _positions(h)
    for op in h:
        if op.kind != WRITE:
            continue
        want = op.itr - delta
        if want < 1:
            continue
        for k in range(n_workers):
            dep = Op(READ, k, op.chunk, want)
            if dep in pos and pos[dep] > pos[op]:
                return False
    return True


def satisfies_read_barrier(h: History, n_workers: int) -> bool:
    """Read barrier: forall i,j,k  w_k[pi_k][alpha] < r_i[pi_j][alpha+1]."""
    pos = _positions(h)
    for op in h:
        if op.kind != READ or op.itr < 2:
            continue
        for k in range(n_workers):
            dep = Op(WRITE, k, k, op.itr - 1)
            if dep in pos and pos[dep] > pos[op]:
                return False
    return True


def satisfies_write_barrier(h: History, n_workers: int) -> bool:
    """Write barrier: forall i,j,k  r_k[pi_j][alpha] < w_i[pi_i][alpha]."""
    pos = _positions(h)
    for op in h:
        if op.kind != WRITE:
            continue
        for k in range(n_workers):
            for j in range(n_workers):
                dep = Op(READ, k, j, op.itr)
                if dep in pos and pos[dep] > pos[op]:
                    return False
    return True


def satisfies_bsp(h: History, n_workers: int) -> bool:
    return (satisfies_read_barrier(h, n_workers)
            and satisfies_write_barrier(h, n_workers))


def satisfies_rcwc(h: History, n_workers: int, delta: int = 0) -> bool:
    return (satisfies_read_constraint(h, delta)
            and satisfies_write_constraint(h, n_workers, delta))


# ---------------------------------------------------------------------------
# Definition 4 / Theorem-5 correctness conditions
# ---------------------------------------------------------------------------

def is_strictly_sequential(h: History, n_workers: int) -> bool:
    """Global Def-4 check: iterations do not interleave at all; within each
    iteration every read precedes every write; iteration numbers increase."""
    cur = 0
    phase = WRITE  # so that the first op (a read of itr 1) bumps cur
    for op in h:
        if op.itr == cur + 1:
            if phase != WRITE and cur != 0:
                return False  # previous iteration had no writes yet? malformed
            cur += 1
            phase = READ
        elif op.itr != cur:
            return False
        if op.kind == READ:
            if phase == WRITE:
                return False  # read after a write within the same iteration
        else:
            phase = WRITE
    return True


def chunk_projection(h: History, chunk: int) -> list[Op]:
    """The sub-history of ops touching one partition — the unit on which
    the Theorem-5 conditions (and the sharded backend's per-shard
    histories) are defined."""
    return [op for op in h if op.chunk == chunk]


def is_order_preserving_merge(merged: History,
                              parts: Sequence[History]) -> bool:
    """True iff every ``part`` appears as a subsequence of ``merged`` and
    ``merged`` contains exactly the ops of the parts — the invariant the
    distributed history merge must maintain (each shard's local order is
    authoritative for the chunks it owns)."""
    if len(merged) != sum(len(p) for p in parts):
        return False
    for part in parts:
        it = iter(merged)
        if not all(any(op == m for m in it) for op in part):
            return False
    return True


def is_sequentially_correct(h: History, n_workers: int) -> bool:
    """Per-partition conditions from the proof of Theorem 5:
    projecting the history onto any single partition gives (1) no
    inter-iteration interleaving, (2) reads-before-write within an iteration,
    (3) consecutive iterations.

    ``n_workers`` bounds the default chunk range; histories with more
    chunks than workers (e.g. the distributed train path, where one logical
    worker owns many chunks) are handled by projecting every chunk id that
    actually appears."""
    chunks = set(range(n_workers)) | {op.chunk for op in h}
    for chunk in sorted(chunks):
        proj = chunk_projection(h, chunk)
        cur = 0
        wrote = True  # allows the first iteration to open
        for op in proj:
            if op.itr == cur + 1:
                if not wrote:
                    return False  # previous iteration never wrote this chunk
                cur += 1
                wrote = False
            elif op.itr != cur:
                return False  # skipped or went backwards
            if op.kind == WRITE:
                wrote = True
            elif wrote:
                return False  # read after this iteration's write
    return True


# ---------------------------------------------------------------------------
# Semantic interpreter — execute a history, compare with sequential result
# ---------------------------------------------------------------------------

UpdateFn = Callable[[int, np.ndarray], np.ndarray]
# f(worker, full_theta) -> new value for worker's chunk


def default_update(n_workers: int, dim: int, seed: int = 0) -> UpdateFn:
    """A generic non-commuting fixed-point update: theta_i <- A_i @ theta + b_i.
    Non-symmetric A_i makes any mis-ordering numerically visible."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_workers, dim, n_workers * dim)) * 0.1
    b = rng.normal(size=(n_workers, dim))

    def f(worker: int, theta: np.ndarray) -> np.ndarray:
        return A[worker] @ theta + b[worker]

    return f


def execute_history(h: History, n_workers: int, dim: int,
                    update: UpdateFn | None = None,
                    theta0: np.ndarray | None = None) -> np.ndarray:
    """Run the reads/writes of ``h`` against a store.  Worker-local read
    buffers accumulate the chunk values each worker saw for its current
    iteration; a write applies the update function to the buffered snapshot.
    Returns the final concatenated theta."""
    update = update or default_update(n_workers, dim)
    store = (np.zeros((n_workers, dim)) if theta0 is None
             else theta0.reshape(n_workers, dim).copy())
    # buffers[worker][itr][chunk] — a worker may legally begin reading for
    # iteration alpha+1 before issuing its own iteration-alpha write (cf. H2)
    buffers: dict[int, dict[int, dict[int, np.ndarray]]] = {
        i: {} for i in range(n_workers)}
    for op in h:
        if op.kind == READ:
            buffers[op.worker].setdefault(op.itr, {})[op.chunk] = \
                store[op.chunk].copy()
        else:
            snap_chunks = buffers[op.worker].pop(op.itr)
            snap = np.concatenate([snap_chunks[j] for j in range(n_workers)])
            store[op.chunk] = update(op.worker, snap)
    return store.reshape(-1)


def sequential_result(n_workers: int, n_iters: int, dim: int,
                      update: UpdateFn | None = None,
                      theta0: np.ndarray | None = None) -> np.ndarray:
    """Ground truth: Algorithm 1 executed single-threaded."""
    update = update or default_update(n_workers, dim)
    theta = (np.zeros(n_workers * dim) if theta0 is None else theta0.copy())
    for _ in range(n_iters):
        snap = theta.copy()
        new = [update(i, snap) for i in range(n_workers)]
        theta = np.concatenate(new)
    return theta


# ---------------------------------------------------------------------------
# Paper's example histories (Fig 3)
# ---------------------------------------------------------------------------

def paper_h1() -> list[Op]:
    return [r(1, 1, 1), r(1, 2, 1), r(2, 1, 1), r(2, 2, 1), w(1, 1, 1),
            w(2, 2, 1), r(1, 1, 2), r(1, 2, 2), r(2, 1, 2), r(2, 2, 2),
            w(1, 1, 2), w(2, 2, 2)]


def paper_h2() -> list[Op]:
    return [r(1, 1, 1), r(1, 2, 1), r(2, 1, 1), r(2, 2, 1), w(2, 2, 1),
            r(1, 2, 2), w(1, 1, 1), r(1, 1, 2), r(2, 1, 2), r(2, 2, 2),
            w(1, 1, 2), w(2, 2, 2)]


def paper_h3() -> list[Op]:
    return [r(1, 1, 1), r(1, 2, 1), w(1, 1, 1), r(2, 1, 1), r(2, 2, 1),
            w(2, 2, 1), r(1, 1, 2), r(1, 2, 2), w(1, 1, 2), r(2, 1, 2),
            r(2, 2, 2), w(2, 2, 2)]


def normalize_history(h: Iterable[Op], base: int = 1) -> list[Op]:
    """Shift worker/chunk ids to 0-based (paper figures use 1-based)."""
    return [Op(o.kind, o.worker - base, o.chunk - base, o.itr) for o in h]
