"""Synchronization-mode configuration for the JAX training stack.

Maps the paper's two synchronization regimes onto sharded-training layouts:

  * ``bsp``         — the Algorithm-2a baseline: parameters replicated over
    the data-parallel axes; one global gradient all-reduce per step is the
    read barrier (every worker's iteration-alpha+1 reads wait on *all*
    iteration-alpha writes).
  * ``datacentric`` — the paper's contribution mapped to SPMD: the parameter
    database is *sharded* over the data axis (partition set Pi = per-layer
    weight shards).  Reads are per-partition all-gathers, writes are
    per-partition reduce-scatters; XLA's dataflow graph enforces exactly the
    RC/WC ordering (all-gather of layer j waits only on layer j's shard), so
    per-partition communication overlaps compute — the Theorem-3 concurrency.

The tables below are *logical axis → mesh axis preference lists*; the
sharding engine in :mod:`repro.launch.sharding` resolves them against a
concrete mesh with divisibility fallbacks.
"""
from __future__ import annotations

import dataclasses

BSP = "bsp"
DATACENTRIC = "datacentric"
SSP = "ssp"

# Logical parameter axes used by the model zoo:
#   vocab     — embedding / lm-head vocabulary dim
#   embed     — d_model dims of weight matrices (the FSDP shard dim)
#   ffn       — feed-forward hidden dim
#   heads     — flattened (n_heads * head_dim) projection dim
#   kv_heads  — flattened (n_kv_heads * head_dim) projection dim
#   experts   — MoE expert dim
#   layers    — stacked scan dim (never sharded)
#   batch/seq/kv_seq — activation & cache dims

_TP_RULES = {
    "vocab": ("model",),
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "layers": (),
}

RULES = {
    # data-centric: parameter database sharded over `data` (ZeRO-3 partitions)
    DATACENTRIC: {**_TP_RULES, "embed": ("data",)},
    # bsp: parameters replicated over `data`; only tensor-parallel sharding
    BSP: {**_TP_RULES, "embed": ()},
    # ssp: bounded-staleness baseline; shards the database like data-centric
    SSP: {**_TP_RULES, "embed": ("data",)},
}

ACTIVATION_RULES = {
    "batch": (("pod", "data"), ("data",)),   # first spec that divides wins
    "seq": (),
    "kv_seq": ("model",),                    # SP fallback for long caches
    "act_embed": (),
    "act_vocab": ("model",),
}


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How parameter reads/writes are synchronized during training."""
    mode: str = DATACENTRIC          # "bsp" | "datacentric" | "ssp"
    delta: int = 0                   # admissible staleness (Sec 7); 0 = exact
    compression: str = "none"        # "none" | "int8" gradient compression
    remat: str = "full"              # "none" | "full" | "dots"
    # per-partition-group delays (Sec 7.1 per-chunk version arrays):
    group_delays: tuple[tuple[str, int], ...] = ()
    # ring-buffer layout: True/False force the packed (grouped, fused-gather)
    # layout; None follows REPRO_KERNEL_IMPL (pdb/jax_backend.py)
    packed_ring: bool | None = None

    def __post_init__(self):
        if self.mode not in (BSP, DATACENTRIC, SSP):
            raise ValueError(f"unknown sync mode {self.mode!r}")
        if self.delta < 0:
            raise ValueError("delta must be >= 0")

    @property
    def param_rules(self) -> dict:
        return RULES[self.mode]

    def delay_for(self, path: tuple) -> int:
        """Resolve a pytree path to its group delay (longest-prefix match on
        the path's string form); defaults to the uniform delta."""
        s = "/".join(getattr(p, "key", str(p)) for p in path)
        best = self.delta
        best_len = -1
        for prefix, d in self.group_delays:
            if s.startswith(prefix) and len(prefix) > best_len:
                best, best_len = d, len(prefix)
        return best

    def to_policy(self, n_workers: int, n_chunks: int | None = None):
        """The ParameterDB consistency policy equivalent of this sync mode
        (host-side backends: threads, in-process replay, simulator)."""
        from ..pdb.policies import make_policy
        name = {BSP: "bsp", DATACENTRIC: "dc", SSP: "ssp"}[self.mode]
        return make_policy(name, n_workers, self.delta, n_chunks)
