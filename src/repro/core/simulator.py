"""Discrete-event simulation of parallel ML execution (paper Sec 6).

Models ``p`` workers executing the Def-3 program (read all chunks, compute,
write own chunk) under any consistency policy from
:mod:`repro.pdb.policies` ("bsp", "dc", "dc-array", "ssp", "hogwild").
Cost model (calibrated against the paper's Sec-6 numbers in benchmarks/):

  * each read / write op has a fixed latency (``read_cost`` / ``write_cost``:
    a shared-store round trip) and workers issue their ops serially;
  * BSP charges a barrier-crossing cost ``barrier_cost * p`` per barrier per
    iteration (centralized sense-barrier wakeup storm);
  * data-centric charges the Sec-5 admission-check cost per op: O(1) for
    reads (version compare), ``check_cost * p`` for writes (bit-vector scan)
    — the overhead the paper uses to explain the declining improvement for
    SGD at high worker counts;
  * compute times are lognormal with configurable skew, identical draws
    across policies for a given seed, so makespan differences are purely
    synchronization effects.

Why data-centric wins here (the paper's Sec-6.1 explanation): under BSP the
read barrier forces *every* worker's p reads to happen after the slowest
write — p*read_cost sits on the critical path of every worker, every
iteration.  Under RC/WC, a worker that finished early performs its write and
p-1 of its next-iteration reads while the straggler is still computing; only
the straggler's own chunk's read remains exposed.  The read/write latency is
absorbed by off-critical-path workers.

Time is in milliseconds.  All runs are deterministic given ``seed``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math

import numpy as np

from ..pdb.policies import make_policy

READ, COMPUTE, WRITE, DONE = "read", "compute", "write", "done"


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_workers: int = 8
    n_iters: int = 50
    policy: str = "dc"                 # "bsp" | "dc" | "dc-array" | "ssp" | "hogwild"
    delta: float = 0.0
    compute_mu: float = 8.0            # mean compute per iteration (ms)
    compute_sigma: float = 0.27        # lognormal sigma (task-time skew)
    read_cost: float = 0.127           # latency per chunk read (server RTT)
    write_cost: float = 0.198          # latency per chunk write
    check_cost: float = 0.036          # DC admission re-check, x p, per op
    barrier_cost: float = 0.087        # BSP barrier wakeup, x p, per barrier
    barrier_base: float = 2.06         # BSP fixed poll latency per crossing
    concurrent_reads: bool = True      # worker sends all read requests at once
    straggler_prob: float = 0.0
    straggler_factor: float = 8.0
    backup_tasks: bool = False         # speculative re-execution of stragglers
    backup_factor: float = 3.0         # backup kicks in at factor x mu
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    makespan: float
    total_block_time: float
    per_worker_finish: list[float]

    def speedup_vs(self, serial_makespan: float) -> float:
        return serial_makespan / self.makespan


@dataclasses.dataclass
class _Worker:
    itr: int = 1
    phase: str = READ
    unread: set = dataclasses.field(default_factory=set)
    inflight: int = 0
    blocked_since: float | None = None
    read_barrier_paid: bool = False   # BSP: one barrier charge per phase
    write_barrier_paid: bool = False
    finish: float = 0.0


def _compute_times(cfg: SimConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    sigma = cfg.compute_sigma
    mu_ln = math.log(cfg.compute_mu) - 0.5 * sigma * sigma
    t = rng.lognormal(mu_ln, sigma, size=(cfg.n_workers, cfg.n_iters))
    if cfg.straggler_prob > 0:
        mask = rng.random((cfg.n_workers, cfg.n_iters)) < cfg.straggler_prob
        t = np.where(mask, t * cfg.straggler_factor, t)
    if cfg.backup_tasks:
        t = np.minimum(t, cfg.backup_factor * cfg.compute_mu)
    return t


def simulate(cfg: SimConfig) -> SimResult:
    sched = make_policy(cfg.policy, cfg.n_workers, cfg.delta)
    times = _compute_times(cfg)
    p = cfg.n_workers
    is_bsp = cfg.policy == "bsp"

    workers = [_Worker(unread=set(range(p))) for _ in range(p)]
    events: list[tuple[float, int, str, int]] = []
    seq = 0
    block_time = 0.0
    blocked: set[int] = set()

    def push(t: float, kind: str, wid: int) -> None:
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, wid))
        seq += 1

    def unblock(w: _Worker, wid: int, now: float) -> None:
        nonlocal block_time
        if w.blocked_since is not None:
            block_time += now - w.blocked_since
            w.blocked_since = None
            blocked.discard(wid)

    def try_advance(now: float, wid: int) -> None:
        w = workers[wid]
        if w.phase == READ:
            cand = [j for j in sorted(w.unread)
                    if sched.can_read(wid, j, w.itr)]
            if cand:
                unblock(w, wid, now)
                lat = cfg.read_cost
                if is_bsp and not w.read_barrier_paid:
                    lat += cfg.barrier_base + cfg.barrier_cost * p
                    w.read_barrier_paid = True
                if not is_bsp:
                    lat += cfg.check_cost * p   # deferred-op re-check scan
                if cfg.concurrent_reads:
                    # issue every admissible read at once (request-based
                    # server: responses arrive independently)
                    for j in cand:
                        w.unread.discard(j)
                        w.inflight += 1
                        push(now + lat, f"rdone:{j}", wid)
                else:
                    j = cand[0]
                    w.unread.discard(j)
                    w.inflight += 1
                    push(now + lat, f"rdone:{j}", wid)
            elif w.unread or w.inflight:
                if w.unread and w.blocked_since is None:
                    w.blocked_since = now
                    blocked.add(wid)
            else:
                w.phase = COMPUTE
                push(now + times[wid, w.itr - 1], "cdone", wid)
        elif w.phase == WRITE:
            if sched.can_write(wid, wid, w.itr):
                unblock(w, wid, now)
                lat = cfg.write_cost
                if is_bsp:
                    if not w.write_barrier_paid:
                        lat += cfg.barrier_base + cfg.barrier_cost * p
                        w.write_barrier_paid = True
                else:
                    lat += cfg.check_cost * p   # bit-vector scan
                push(now + lat, "wdone", wid)
                w.phase = "write-inflight"
            else:
                if w.blocked_since is None:
                    w.blocked_since = now
                    blocked.add(wid)

    def wake_blocked(now: float) -> None:
        for wid in list(blocked):
            try_advance(now, wid)

    for wid in range(p):
        try_advance(0.0, wid)

    makespan = 0.0
    while events:
        now, _, kind, wid = heapq.heappop(events)
        w = workers[wid]
        if kind.startswith("rdone:"):
            j = int(kind.split(":")[1])
            w.inflight -= 1
            sched.did_read(wid, j, w.itr)
            wake_blocked(now)       # a read may unblock pending writes
            try_advance(now, wid)
        elif kind == "cdone":
            w.phase = WRITE
            try_advance(now, wid)
        elif kind == "wdone":
            sched.did_write(wid, wid, w.itr)
            w.itr += 1
            if w.itr > cfg.n_iters:
                w.phase = DONE
                w.finish = now
                makespan = max(makespan, now)
            else:
                w.phase = READ
                w.unread = set(range(p))
                w.read_barrier_paid = False
                w.write_barrier_paid = False
            wake_blocked(now)       # a write may unblock pending reads
            if w.phase == READ:
                try_advance(now, wid)

    if blocked:
        raise RuntimeError(
            f"simulation deadlocked with workers {sorted(blocked)} blocked "
            f"(policy={cfg.policy}, delta={cfg.delta})")
    return SimResult(makespan, block_time, [w.finish for w in workers])


def serial_makespan(cfg: SimConfig) -> float:
    """Single-worker execution time of the same total work (for speedup
    curves, Fig 2b): all p partitions' compute done serially, no sync."""
    times = _compute_times(cfg)
    return float(times.sum()) + cfg.n_iters * cfg.n_workers * (
        cfg.read_cost + cfg.write_cost)


def improvement_pct(cfg_kwargs: dict, delta: float = 0.0) -> float:
    """Paper's headline metric: (T_bsp - T_dc) / T_bsp * 100 for the same
    workload (same seed => same compute-time draws)."""
    bsp = simulate(SimConfig(policy="bsp", **cfg_kwargs))
    dc = simulate(SimConfig(policy="dc", delta=delta, **cfg_kwargs))
    return (bsp.makespan - dc.makespan) / bsp.makespan * 100.0


def trimmed_mean(xs: list[float], drop: int = 2) -> float:
    """The paper's statistic: mean after dropping the `drop` fastest and
    slowest of 10 runs."""
    s = sorted(xs)
    core = s[drop:len(s) - drop] if len(s) > 2 * drop else s
    return float(np.mean(core))


def amdahl_speedup(p: int, serial_fraction: float = 0.01) -> float:
    """Theoretical asynchronous limit curve from Fig 2b."""
    return 1.0 / (serial_fraction + (1.0 - serial_fraction) / p)
