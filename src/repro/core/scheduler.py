"""Compatibility shim: the admission engines now live in
:mod:`repro.pdb.policies` as the *consistency policies* of the unified
ParameterDB subsystem.  This module keeps the historical names alive
(`*Scheduler`, ``make_scheduler``) for existing callers and tests; new code
should import from :mod:`repro.pdb` directly.
"""
from __future__ import annotations

from ..pdb.policies import (  # noqa: F401
    BSPPolicy as BSPScheduler,
    BitVectorPolicy as BitVectorScheduler,
    DeltaPolicy as DeltaScheduler,
    Policy as Scheduler,
    SSPPolicy as SSPScheduler,
    make_policy as make_scheduler,
    random_schedule,
)
