"""The data-centric synchronization protocols of Secs 5 and 7.1.

Three admission engines share one interface (``can_read / can_write`` test
admissibility; ``did_read / did_write`` record completion):

  * :class:`BitVectorScheduler`  — the Sec-5 protocol verbatim: one bit per
    worker per chunk gates writes; a per-chunk iteration number gates reads.
    Enforces exact sequential semantics (delta = 0).
  * :class:`DeltaScheduler`      — the Sec-7.1 revised protocol: a per-chunk
    array of last-read iteration numbers; admissible delay ``delta >= 0``.
    ``delta=0`` coincides with :class:`BitVectorScheduler`; ``delta=inf``
    degenerates to Hogwild!-style fully asynchronous execution.
  * :class:`BSPScheduler`        — the Algorithm-2a baseline: global read and
    write barriers expressed in the same admission interface.

These engines are *pure bookkeeping* — they never block.  Blocking wrappers
live in :mod:`repro.core.threaded`; the discrete-event simulator in
:mod:`repro.core.simulator` drives them directly.
"""
from __future__ import annotations

import math
from typing import Protocol


class Scheduler(Protocol):
    def can_read(self, worker: int, chunk: int, itr: int) -> bool: ...
    def can_write(self, worker: int, chunk: int, itr: int) -> bool: ...
    def did_read(self, worker: int, chunk: int, itr: int) -> None: ...
    def did_write(self, worker: int, chunk: int, itr: int) -> None: ...


class BitVectorScheduler:
    """Sec 5: 'a write on pi_i can be executed if this chunk has been read by
    all the worker processes in their alpha-th iterations' (bit vector), and
    'a read [at alpha+1] can be executed if [the chunk's] iteration number is
    one less than the iteration number in the read operation'."""

    def __init__(self, n_workers: int, n_chunks: int | None = None):
        self.p = n_workers
        self.m = n_chunks if n_chunks is not None else n_workers
        # start as if freshly written (version 0, bits zeroed): iteration-1
        # writes must wait for every worker's iteration-1 read of the chunk
        self.bits = [[False] * self.p for _ in range(self.m)]
        self.version = [0] * self.m  # iteration number of last executed write

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.version[chunk] == itr - 1

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.bits[chunk][worker] = True

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return all(self.bits[chunk])

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.bits[chunk] = [False] * self.p  # 'all bits are set to zero'
        self.version[chunk] = itr


class DeltaScheduler:
    """Sec 7.1: per-chunk last-read iteration array + chunk version.

    Read  r_i[pi_j][alpha] admissible iff version[j] >= alpha - 1 - delta.
    Write w_i[pi_i][alpha] admissible iff min_k last_read[i][k] >= alpha - delta.
    """

    def __init__(self, n_workers: int, delta: float = 0,
                 n_chunks: int | None = None):
        if delta < 0:
            raise ValueError("delta must be >= 0")
        self.p = n_workers
        self.m = n_chunks if n_chunks is not None else n_workers
        self.delta = delta
        self.version = [0] * self.m
        self.last_read = [[0] * self.p for _ in range(self.m)]

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.version[chunk] >= itr - 1 - self.delta

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.last_read[chunk][worker] = max(self.last_read[chunk][worker], itr)

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return min(self.last_read[chunk]) >= itr - self.delta

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.version[chunk] = max(self.version[chunk], itr)

    @property
    def hogwild(self) -> bool:
        return math.isinf(self.delta)


class BSPScheduler:
    """Algorithm 2a expressed as admission predicates.

    Read barrier:  no read of iteration alpha+1 until *every* worker's write
    of iteration alpha has executed.
    Write barrier: no write of iteration alpha until *every* worker has
    finished *all* its reads of iteration alpha.
    """

    def __init__(self, n_workers: int, n_chunks: int | None = None):
        self.p = n_workers
        self.m = n_chunks if n_chunks is not None else n_workers
        self.writes_done = [0] * self.p      # writes_done[i] = last iter i wrote
        self.reads_done = [[0] * self.m for _ in range(self.p)]
        # reads_done[i][j] = last iter in which worker i read chunk j

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return all(v >= itr - 1 for v in self.writes_done)

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.reads_done[worker][chunk] = max(self.reads_done[worker][chunk], itr)

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return all(self.reads_done[i][j] >= itr
                   for i in range(self.p) for j in range(self.m))

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.writes_done[worker] = max(self.writes_done[worker], itr)


def random_schedule(policy: str, n_workers: int, n_iters: int,
                    seed: int = 0, delta: float = 0) -> list:
    """Generate a random admissible execution history: at every step pick a
    uniformly random worker whose next Def-3 operation is admissible under
    the policy.  Used by the hypothesis property tests (every such history
    must be sequentially correct — Theorems 1/2) and as a fuzzer for the
    admission engines (total progress = deadlock freedom)."""
    import random as _random

    from .history import Op, READ, WRITE

    rng = _random.Random(seed)
    sched = make_scheduler(policy, n_workers, delta)
    # per-worker state: current iteration, unread chunks, write pending
    itr = [1] * n_workers
    unread = [set(range(n_workers)) for _ in range(n_workers)]
    history: list[Op] = []
    total = n_workers * n_iters * (n_workers + 1)
    while len(history) < total:
        moves: list[Op] = []
        for i in range(n_workers):
            if itr[i] > n_iters:
                continue
            if unread[i]:
                moves += [Op(READ, i, j, itr[i]) for j in sorted(unread[i])
                          if sched.can_read(i, j, itr[i])]
            elif sched.can_write(i, i, itr[i]):
                moves.append(Op(WRITE, i, i, itr[i]))
        if not moves:
            raise RuntimeError(
                f"deadlock in random_schedule(policy={policy})")
        op = rng.choice(moves)
        if op.kind == READ:
            sched.did_read(op.worker, op.chunk, op.itr)
            unread[op.worker].discard(op.chunk)
        else:
            sched.did_write(op.worker, op.chunk, op.itr)
            itr[op.worker] += 1
            unread[op.worker] = set(range(n_workers))
        history.append(op)
    return history


def make_scheduler(policy: str, n_workers: int, delta: float = 0,
                   n_chunks: int | None = None) -> Scheduler:
    if policy == "bsp":
        return BSPScheduler(n_workers, n_chunks)
    if policy == "dc":
        if delta == 0:
            return BitVectorScheduler(n_workers, n_chunks)
        return DeltaScheduler(n_workers, delta, n_chunks)
    if policy == "dc-array":  # Sec-7.1 engine even at delta=0
        return DeltaScheduler(n_workers, delta, n_chunks)
    raise ValueError(f"unknown policy {policy!r}")
