"""Compatibility shim: the deterministic delta-staleness engine (paper
Sec 7, TPU-native) now lives in :mod:`repro.pdb.jax_backend` as the JAX
device backend of the unified ParameterDB.  The historical entry points are
re-exported here; new code should import from :mod:`repro.pdb` directly.
"""
from __future__ import annotations

from ..pdb.jax_backend import (  # noqa: F401
    DelayedState,
    PyTree,
    init_delayed_state,
    make_delayed_step,
)
