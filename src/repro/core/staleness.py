"""Deterministic delta-staleness training engine (paper Sec 7, TPU-native).

On SPMD hardware there is no intra-program asynchrony, so the paper's
admissible-delay semantics is mapped onto *steps*: the gradient at step
``alpha`` is evaluated at the parameters of step ``alpha - delta`` and
applied to the parameters of step ``alpha``.  A ring buffer holds the last
``delta + 1`` parameter versions; per-partition-group delays (the Sec-7.1
per-chunk version arrays) let different parts of the model read different
staleness levels.

``delta = 0`` is bit-identical to synchronous training (asserted in
tests/test_staleness_jax.py) — the Sec-4 sequential-correctness guarantee.
``delta = inf`` has no finite buffer; the engine caps at the configured
delta, which is the bounded-staleness regime of SSP/parameter-server work
the paper positions itself against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass
class DelayedState:
    params: PyTree          # current theta[alpha]
    hist: PyTree            # stacked (delta+1, ...) ring buffer of versions
    ptr: jnp.ndarray        # ring position of theta[alpha]
    opt_state: PyTree
    step: jnp.ndarray

    def tree_flatten(self):
        return ((self.params, self.hist, self.ptr, self.opt_state, self.step),
                None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    DelayedState,
    lambda s: s.tree_flatten(),
    lambda aux, ch: DelayedState.tree_unflatten(aux, ch))


def init_delayed_state(params: PyTree, opt_init: Callable[[PyTree], PyTree],
                       delta: int) -> DelayedState:
    """Ring buffer starts filled with theta[0] (the paper's convention that
    reads clipped below iteration 1 see the initial values)."""
    hist = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (delta + 1,) + x.shape), params)
    return DelayedState(params=params, hist=hist,
                        ptr=jnp.zeros((), jnp.int32),
                        opt_state=opt_init(params),
                        step=jnp.zeros((), jnp.int32))


def make_delayed_step(
    grad_fn: Callable[[PyTree, Any], tuple[jnp.ndarray, PyTree]],
    opt_update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]],
    delta: int,
    delay_for: Callable[[tuple], int] | None = None,
) -> Callable[[DelayedState, Any], tuple[DelayedState, dict]]:
    """Build a jit-able delayed-gradient step.

    grad_fn(params, batch) -> (loss, grads)
    opt_update(grads, opt_state, params) -> (new_params, new_opt_state)
    delay_for(path) -> per-leaf delay in [0, delta]; default: uniform delta.
    """
    size = delta + 1

    def read_stale(state: DelayedState) -> PyTree:
        def pick(path, hist_leaf):
            d = delta if delay_for is None else min(delay_for(path), delta)
            idx = jnp.mod(state.ptr - d, size)
            return jax.lax.dynamic_index_in_dim(hist_leaf, idx, axis=0,
                                                keepdims=False)
        return jax.tree_util.tree_map_with_path(pick, state.hist)

    def step(state: DelayedState, batch: Any) -> tuple[DelayedState, dict]:
        stale_params = read_stale(state)
        loss, grads = grad_fn(stale_params, batch)
        new_params, new_opt = opt_update(grads, state.opt_state, state.params)
        new_ptr = jnp.mod(state.ptr + 1, size)
        new_hist = jax.tree.map(
            lambda h, p: jax.lax.dynamic_update_index_in_dim(
                h, p.astype(h.dtype), new_ptr, axis=0),
            state.hist, new_params)
        new_state = DelayedState(params=new_params, hist=new_hist,
                                 ptr=new_ptr, opt_state=new_opt,
                                 step=state.step + 1)
        return new_state, {"loss": loss, "staleness": jnp.asarray(delta)}

    return step
