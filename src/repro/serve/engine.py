"""Request-level serving engine: continuous batching over a live ParameterDB.

The engine owns ``batch_size`` sequence *slots* backed by one paged KV
cache (:mod:`repro.serve.paged_cache`).  Requests arrive on an open-loop
clock (:mod:`repro.serve.workload`); the scheduler joins a new sequence
the moment a slot frees up and evicts it the moment it finishes — decode
never drains the batch.  Every decode step runs the full (B,) batch with
per-sequence positions; idle slots sit at pos 0 with their page tables on
the junk page, so they cost one masked lane and touch no live state.

Two prompt paths:

* **Whole-prompt prefill** (``prefill_chunk=0``, the PR-5 baseline): a
  dense B=1 prefill at admission, scattered into freshly allocated pages.
  A long prompt stalls every decoding sequence for its full prefill.
* **Chunked prefill** (``prefill_chunk=C``): each scheduler tick runs at
  most one C-token chunk of the oldest pending prompt *alongside* the
  decode batch — no drain barrier, decode latency stays bounded by one
  chunk.  A prefilling slot keeps its device page table on the junk page
  and carries recurrent state outside the batch cache until *activation*
  (``make_activate_fn``), so interleaved decode steps can't touch it.
  Chunks are end-aligned when sound (attention-only model, prompt within
  the smallest ring): the final chunk starts at ``S - C``, overlapping
  its predecessor by recomputing a few positions into the slot's private
  pages, so the prompt needs no padding, one compiled chunk shape covers
  every length, and the first token comes straight from the final
  chunk's logits.  When overlap is unsound (recurrent carry would eat
  the overlapped tokens twice, or a windowed ring wraps mid-prompt) the
  sub-chunk remainder is instead teacher-forced through the decode path
  one token per tick ("tail" phase, logits discarded until the prompt is
  exhausted) — which is also the fast path for a near-complete prefix
  hit (a fully cached prompt costs a single decode tick).

With ``prefix_cache=True`` a radix trie over prompt tokens
(:mod:`repro.serve.prefix_cache`) lets a new request adopt the physical
pages of its longest already-computed prefix: full page matches are
shared read-only under refcounts, a partially matched tail page is
adopted by copy (copy-on-write at the divergence point), and a live slot
about to overwrite a page it still shares (ring wrap) gets a
copy-on-write page first.  Finished prefills publish their full pages
back into the trie; LRU eviction over unreferenced trie leaves feeds the
allocator free list under pressure.

Sampling: ``temperature=0`` is greedy argmax; otherwise softmax sampling
with nucleus ``top_p``, keyed per request as
``fold_in(fold_in(PRNGKey(sample_seed), rid), token_index)`` — the draw
depends only on the request and token index, never on batch composition
or scheduling, so continuous and static schedules stay token-identical
even when sampling.

Parameters are never owned: each prefill and each decode step reads the
current tree from a :class:`repro.serve.live_db.LiveParamDB` (or
:class:`StaticParams`), so a trainer can publish new weights mid-serve
under the data-centric admissible-delay contract.

The classic static baseline is the same engine with ``continuous=False``:
admission only happens when every slot is free (and waits until a full
batch has arrived), which reintroduces the drain-the-batch barrier — the
difference between the two modes is purely scheduling policy, measured by
benchmarks/serve_bench.py.

Two clocks: ``"wall"`` (arrivals in seconds, ``time.perf_counter``) for
benchmarking, ``"steps"`` (arrivals in scheduler-tick indices, a virtual
clock) for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from collections.abc import Mapping
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import (decode_step, init_chunk_carry, prefill,
                                  prefill_chunk)
from .live_db import StaticParams
from .paged_cache import (ATTN_KINDS, PageAllocator, init_paged_cache,
                          make_activate_fn, make_copy_page_fn, make_evict_fn,
                          make_join_fn)
from .prefix_cache import PrefixCache
from .workload import Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (model architecture comes from ModelConfig)."""
    batch_size: int = 4          # sequence slots (B_max)
    page_size: int = 8           # tokens per KV page
    cache_len: int = 128         # logical ring length for full-attn layers
    continuous: bool = True      # False = static drain-the-batch baseline
    clock: str = "wall"          # "wall" (seconds) | "steps" (ticks)
    warmup: bool = True          # compile before starting the clock
    prefill_chunk: int = 0       # chunk size; 0 = whole-prompt prefill
    prefix_cache: bool = False   # share prompt-prefix pages across requests
    prefix_seqs: int = -1        # pool headroom for retained prefixes, in
    #                              sequences' worth of pages (-1: batch_size)
    temperature: float = 0.0     # 0 = greedy argmax
    top_p: float = 1.0           # nucleus sampling mass (with temperature)
    sample_seed: int = 0         # base PRNG seed for sampling

    def __post_init__(self):
        if self.clock not in ("wall", "steps"):
            raise ValueError(f"unknown clock {self.clock!r}")
        if self.prefix_cache and self.prefill_chunk <= 0:
            # prefix adoption rides on the chunked path; default the chunk
            object.__setattr__(self, "prefill_chunk", self.page_size)
        if (self.prefix_cache or self.prefill_chunk > 0) \
                and not self.continuous:
            raise ValueError(
                "prefix_cache / prefill_chunk require continuous=True "
                "(the static baseline keeps whole-prompt prefill)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    arrival: float
    t_first: float               # clock at first token (end of prefill)
    t_done: float                # clock at last token
    tokens: tuple[int, ...]

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival

    @property
    def ttft(self) -> float:
        """Time to first token (queueing + prefill)."""
        return self.t_first - self.arrival


@dataclasses.dataclass
class ServeReport:
    mode: str                    # "continuous" | "static"
    n_requests: int
    total_tokens: int
    duration: float              # clock units (s or ticks)
    tokens_per_sec: float        # tokens / duration (per-tick for "steps")
    latency_p50: float
    latency_p99: float
    ttft_p50: float              # time-to-first-token percentiles
    ttft_p99: float
    decode_steps: int
    prefill_chunks: int          # chunked-prefill device calls issued
    prefix_hit_rate: float       # fraction of prompt tokens adopted
    utilization: float           # mean fraction of live slots per decode step
    outputs: dict[int, tuple[int, ...]]


class _Slot:
    __slots__ = ("req", "phase", "remaining", "tokens", "t_first",
                 "fill_pos", "chunk_starts", "carry", "rows_dev",
                 "rows_host", "shared", "nodes")

    def __init__(self, req: Request):
        self.req = req
        self.phase = "decode"        # "prefill" | "tail" | "decode"
        self.remaining = 0
        self.tokens: list[int] = []
        self.t_first = 0.0
        self.fill_pos = 0            # prompt positions < this are computed
        self.chunk_starts: list[int] = []  # pending prefill-chunk starts
        self.carry: Any = None       # recurrent state during chunked prefill
        self.rows_dev: dict | None = None
        self.rows_host: dict | None = None
        self.shared: dict[int, set] = {}   # {L: logical page idx shared}
        self.nodes: list = []        # trie node refs to release at retire


class ServeEngine:
    """One model, one paged cache, ``batch_size`` sequence slots."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if cfg.frontend == "vision":
            raise NotImplementedError(
                "serving engine is text-only for now; vision archs need "
                "per-request media plumbing through admission and decode")
        self.cfg, self.scfg = cfg, scfg
        # a raw param pytree (a Mapping) gets the frozen handle; anything
        # else exposing get() is treated as a live handle (LiveParamDB)
        self.db = (StaticParams(params)
                   if isinstance(params, Mapping) or not hasattr(params, "get")
                   else params)
        B = scfg.batch_size
        extra = 0
        if scfg.prefix_cache:
            extra = scfg.prefix_seqs if scfg.prefix_seqs >= 0 else B
        self.alloc = PageAllocator(cfg, B, scfg.cache_len, scfg.page_size,
                                   extra_seqs=extra)
        self.cache = init_paged_cache(cfg, B, scfg.cache_len, scfg.page_size,
                                      extra_seqs=extra)
        self._min_L = min(self.alloc.classes)
        if scfg.prefill_chunk > self._min_L:
            raise ValueError(
                f"prefill_chunk {scfg.prefill_chunk} exceeds the smallest "
                f"page-class ring ({self._min_L}); chunk scatter slots "
                "must stay unique within a chunk")
        # prefix adoption shares raw K/V pages — recurrent layers would
        # also need a per-prefix state snapshot, which we don't keep yet;
        # chunked prefill itself works for every layer kind via the carry
        self._all_attn = all(k in ATTN_KINDS for k in cfg.layer_kinds)
        self._can_adopt = scfg.prefix_cache and self._all_attn
        self.prefix = (PrefixCache(self.alloc, scfg.page_size)
                       if scfg.prefix_cache else None)

        self._join = jax.jit(make_join_fn(cfg, scfg.cache_len,
                                          scfg.page_size))
        self._evict = jax.jit(make_evict_fn(cfg, scfg.cache_len,
                                            scfg.page_size))
        self._activate = jax.jit(make_activate_fn(cfg, scfg.cache_len,
                                                  scfg.page_size))
        self._copy = jax.jit(make_copy_page_fn(cfg, scfg.cache_len,
                                               scfg.page_size),
                             static_argnames=("L", "set_pt"))
        self._prefill = jax.jit(lambda p, t: prefill(
            p, t, cfg, cache_len=scfg.cache_len))
        self._chunk = jax.jit(lambda p, c, t, s, r, car: prefill_chunk(
            p, c, t, s, r, car, cfg, scfg.cache_len))
        self._carry0 = init_chunk_carry(cfg)

        sampler = self._make_sampler()
        self._sample = jax.jit(sampler)

        def _step(p, c, tok, pos, rids, ctrs):
            logits, c = decode_step(p, c, tok, pos, cfg)
            return sampler(logits[:, -1], rids, ctrs), c

        self._decode = jax.jit(_step)
        self._tok = np.zeros((B, 1), np.int32)
        self._pos = np.zeros((B,), np.int32)
        self._rid = np.zeros((B,), np.int32)
        self._ctr = np.zeros((B,), np.int32)
        self.slots: list[_Slot | None] = [None] * B
        self._prefill_q: deque[int] = deque()
        self.decode_steps = 0
        self.prefill_chunks = 0
        self._live_slot_steps = 0
        self._finished: list[FinishedRequest] = []

    # -- sampling ---------------------------------------------------------

    def _make_sampler(self) -> Callable:
        """logits (B, V), rids (B,), ctrs (B,) -> next tokens (B,) int32."""
        temp, top_p = self.scfg.temperature, self.scfg.top_p
        if temp <= 0.0:
            def greedy(logits, rids, ctrs):
                return jnp.argmax(logits, -1).astype(jnp.int32)
            return greedy
        base = jax.random.PRNGKey(self.scfg.sample_seed)

        def sample(logits, rids, ctrs):
            lf = logits.astype(jnp.float32) / temp
            if top_p < 1.0:
                srt = jnp.sort(lf, axis=-1)[:, ::-1]
                pr = jax.nn.softmax(srt, axis=-1)
                cum = jnp.cumsum(pr, axis=-1)
                keep = cum - pr < top_p          # smallest nucleus >= top_p
                cutoff = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1)
                lf = jnp.where(lf >= cutoff[:, None], lf, -jnp.inf)

            def row(l, rid, ctr):
                key = jax.random.fold_in(jax.random.fold_in(base, rid), ctr)
                return jax.random.categorical(key, l)

            return jax.vmap(row)(lf, rids, ctrs).astype(jnp.int32)

        return sample

    def _sample_one(self, logits: jnp.ndarray, rid: int, ctr: int) -> int:
        """Sample one token from (1, V) logits (prefill outputs)."""
        return int(self._sample(logits,
                                jnp.asarray([rid], jnp.int32),
                                jnp.asarray([ctr], jnp.int32))[0])

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        if self.scfg.clock == "wall":
            return time.perf_counter() - self._t0
        return self._vnow

    def _advance_to(self, t: float) -> None:
        """Idle fast-forward to the next arrival."""
        if self.scfg.clock == "wall":
            time.sleep(max(0.0, t - self._now()))
        else:
            self._vnow = max(self._vnow, t)

    # -- admission --------------------------------------------------------

    def _free_slot(self) -> int | None:
        for b, s in enumerate(self.slots):
            if s is None:
                return b
        return None

    def _alloc_pages(self, L: int, k: int) -> np.ndarray:
        """Allocate under prefix-cache pressure: evict LRU trie leaves
        into the free list first if the class is short."""
        if self.prefix is not None:
            self.prefix.evict_for(L, k)
        return self.alloc.alloc_pages(L, k)

    def _admit(self, req: Request, now: float) -> None:
        """Whole-prompt prefill admission (the PR-5 baseline path)."""
        params = self.db.get()
        tokens = jnp.asarray([req.prompt], jnp.int32)
        logits, dense = self._prefill(params, tokens)
        first = self._sample_one(logits, req.rid, 0)
        if req.gen_len <= 1:       # prompt-only request: done at prefill
            self._finished.append(FinishedRequest(
                req.rid, req.arrival, now, now, (first,)))
            return
        b = self._free_slot()
        assert b is not None, "admission with no free slot"
        rows = {L: jnp.asarray(ids) for L, ids in
                self.alloc.alloc(b).items()}
        self.cache = self._join(self.cache, dense,
                                jnp.asarray(b, jnp.int32), rows)
        self._tok[b, 0] = first
        self._pos[b] = len(req.prompt)
        self._rid[b] = req.rid
        self._ctr[b] = 1
        s = _Slot(req)
        s.remaining = req.gen_len - 1
        s.tokens = [first]
        s.t_first = now
        self.slots[b] = s

    def _admit_chunked(self, req: Request, now: float) -> None:
        """Chunked-prefill admission: assign a slot and queue it.  The
        adoption lookup and chunk plan are deferred until the slot
        reaches the head of the prefill queue (``_plan_chunks``) — by
        then any in-flight request sharing its prefix has activated and
        published its pages, so concurrent same-prefix arrivals miss at
        most once instead of once per slot."""
        b = self._free_slot()
        assert b is not None, "admission with no free slot"
        s = _Slot(req)
        s.phase = "prefill"
        s.chunk_starts = None     # not planned yet
        self.slots[b] = s
        self._prefill_q.append(b)

    def _plan_chunks(self, b: int) -> None:
        """Adopt any cached prefix, allocate the rest of the slot's
        pages, and plan the chunk schedule."""
        s = self.slots[b]
        req = s.req
        prompt, S = req.prompt, len(req.prompt)
        page = self.scfg.page_size
        C = self.scfg.prefill_chunk

        full, partial = [], None
        if self.prefix is not None and self._can_adopt and S <= self._min_L:
            full, partial = self.prefix.lookup(prompt)
        a_pg = len(full)
        adopt = a_pg * page + (partial[1] if partial else 0)
        # Chunk plan.  Preferred: end-aligned chunks, the last one starting
        # at S - C so it covers the final prompt token and its logits give
        # the first generated token directly — the final chunk may overlap
        # the one before it (or the adopted prefix), recomputing a few
        # positions into the slot's private pages.  Overlap is only sound
        # when no ring wraps during the prompt (S <= the smallest ring;
        # wrapped slots would alias recomputed positions) and no layer
        # carries recurrent state (the carry would consume the overlapped
        # tokens twice).  Otherwise: non-overlapping chunks from the
        # adoption point, with the sub-chunk remainder teacher-forced one
        # token per tick through the decode path ("tail" phase) — for a
        # near-complete prefix hit that tail IS the fast path.
        overlap = (self._all_attn and S <= self._min_L and S >= C
                   and S - adopt > 2)
        if overlap:
            # chunks must only ever write the slot's private pages: cap
            # adoption at the last page boundary <= the final chunk start
            a_pg = min(a_pg, (S - C) // page)
            full, partial = full[:a_pg], None
            base = a_pg * page
            k = -(-(S - base) // C)
            s.chunk_starts = [base + i * C for i in range(k - 1)] + [S - C]
            s.fill_pos = base
        else:
            k = (S - adopt) // C
            s.chunk_starts = [adopt + i * C for i in range(k)]
            s.fill_pos = adopt
        if full:
            self.prefix.lease(full)           # released at retire
            s.nodes += full
        if partial:
            self.prefix.lease([partial[0]])   # guard during the copy below

        rows: dict[int, np.ndarray] = {}
        for L, npp in self.alloc.classes.items():
            ids = np.empty((npp,), np.int32)
            for i, node in enumerate(full):
                ids[i] = node.pages[L]
            ids[a_pg:] = self._alloc_pages(L, npp - a_pg)
            rows[L] = ids
        if partial:
            node, _t = partial
            for L in self.alloc.classes:
                self.cache = self._copy(
                    self.cache, jnp.asarray(node.pages[L], jnp.int32),
                    jnp.asarray(rows[L][a_pg], jnp.int32), L=L,
                    set_pt=False, b=jnp.asarray(0, jnp.int32),
                    idx=jnp.asarray(0, jnp.int32))
            self.prefix.release([node], drop_pages=True)
        self.alloc.install(b, rows)

        s.rows_host = {L: self.alloc.tables[L][b] for L in rows}  # views
        s.rows_dev = {L: jnp.asarray(ids) for L, ids in rows.items()}
        s.carry = self._carry0
        s.shared = {L: set(range(a_pg)) for L in rows}

    def _try_admit(self, queue: deque, now: float, n_left: int) -> bool:
        admitted = False
        chunked = self.scfg.prefill_chunk > 0
        if self.scfg.continuous:
            while queue and self._free_slot() is not None:
                req = queue.popleft()
                if chunked:
                    self._admit_chunked(req, now)
                else:
                    self._admit(req, now)
                admitted = True
        else:
            # static baseline: wait for an empty engine AND a full batch
            # (or the tail of the workload), then admit the whole wave
            want = min(self.scfg.batch_size, n_left)
            if all(s is None for s in self.slots) and len(queue) >= want:
                for _ in range(want):
                    self._admit(queue.popleft(), now)
                    admitted = True
        return admitted

    # -- chunked prefill / activation -------------------------------------

    def _prefill_tick(self, params) -> tuple[int, jnp.ndarray] | None:
        """Run one chunk of the oldest pending prefill.  Returns
        ``(slot, last_logits)`` when that prefill just ran its final
        chunk (activation happens after this tick's decode)."""
        if not self._prefill_q:
            return None
        b = self._prefill_q[0]
        s = self.slots[b]
        if s.chunk_starts is None:     # head of queue: plan against the
            self._plan_chunks(b)       # freshest trie state
            if not s.chunk_starts:     # near-total hit: straight to tail
                self._prefill_q.popleft()
                return b, None
        C = self.scfg.prefill_chunk
        start = s.chunk_starts.pop(0)
        toks = jnp.asarray([s.req.prompt[start:start + C]], jnp.int32)
        logits, self.cache, s.carry = self._chunk(
            params, self.cache, toks, jnp.asarray(start, jnp.int32),
            s.rows_dev, s.carry)
        s.fill_pos = start + C
        self.prefill_chunks += 1
        if not s.chunk_starts:
            self._prefill_q.popleft()
            return b, logits
        return None

    def _activate_slot(self, b: int, last_logits, now: float) -> None:
        """Flip a prefilling slot live: install its page tables and
        recurrent carry, then either take the first token straight from
        the final chunk's logits or trickle the sub-chunk prompt
        remainder through the decode path."""
        s = self.slots[b]
        S = len(s.req.prompt)
        self.cache = self._activate(self.cache, jnp.asarray(b, jnp.int32),
                                    s.rows_dev, s.carry)
        self._rid[b] = s.req.rid
        if s.fill_pos == S:            # chunks covered the whole prompt
            first = self._sample_one(last_logits, s.req.rid, 0)
            s.phase = "decode"
            s.tokens = [first]
            s.remaining = s.req.gen_len - 1
            s.t_first = now
            self._tok[b, 0] = first
            self._pos[b] = S
            self._ctr[b] = 1
            self._insert_prefix(b)
            if s.remaining <= 0:
                self._retire(b, now)
        else:                          # remainder: teacher-forced decode
            s.phase = "tail"
            self._tok[b, 0] = s.req.prompt[s.fill_pos]
            self._pos[b] = s.fill_pos
            self._ctr[b] = 0

    def _insert_prefix(self, b: int) -> None:
        """Publish a freshly prefilled prompt's full pages to the trie."""
        s = self.slots[b]
        if (self.prefix is None or not self._can_adopt
                or len(s.req.prompt) > self._min_L):
            return
        path, new_idx = self.prefix.insert(s.req.prompt, s.rows_host)
        s.nodes += path
        for L in s.shared:
            s.shared[L].update(new_idx)

    # -- copy-on-write ----------------------------------------------------

    def _cow_tick(self) -> None:
        """Before a decode step: any live slot about to write a page it
        shares with the prefix trie (ring wrap back into an adopted or
        published page) gets a private copy, page table repointed in the
        same device call."""
        page = self.scfg.page_size
        for b, s in enumerate(self.slots):
            if s is None or s.phase == "prefill":
                continue
            p = int(self._pos[b])
            for L, shared in s.shared.items():
                if not shared:
                    continue
                pg = (p % L) // page
                if pg not in shared:
                    continue
                src = int(s.rows_host[L][pg])
                dst = int(self._alloc_pages(L, 1)[0])
                self.cache = self._copy(
                    self.cache, jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32), L=L, set_pt=True,
                    b=jnp.asarray(b, jnp.int32),
                    idx=jnp.asarray(pg, jnp.int32))
                s.rows_host[L][pg] = dst   # view into alloc.tables
                self.alloc.decref(L, src)  # drop the slot's share
                shared.discard(pg)

    # -- retire -----------------------------------------------------------

    def _retire(self, b: int, now: float) -> None:
        s = self.slots[b]
        self._finished.append(FinishedRequest(
            s.req.rid, s.req.arrival, s.t_first, now, tuple(s.tokens)))
        if self.prefix is not None and s.nodes:
            self.prefix.release(s.nodes)
        self.cache = self._evict(self.cache, jnp.asarray(b, jnp.int32))
        self.alloc.free_slot(b)
        self._tok[b, 0] = 0
        self._pos[b] = 0
        self._rid[b] = 0
        self._ctr[b] = 0
        self.slots[b] = None

    # -- warmup -----------------------------------------------------------

    def _warmup(self, requests: list[Request]) -> None:
        """Compile every shape the run will hit before the clock starts."""
        params = self.db.get()
        rows = {L: jnp.zeros((npp,), jnp.int32)
                for L, npp in self.alloc.classes.items()}
        if self.scfg.prefill_chunk > 0:
            C = self.scfg.prefill_chunk
            logits, cache, carry = self._chunk(
                params, self.cache, jnp.zeros((1, C), jnp.int32),
                jnp.asarray(0, jnp.int32), rows, self._carry0)
            self._activate(self.cache, jnp.asarray(0, jnp.int32), rows,
                           self._carry0)
            for L in self.alloc.classes:
                for set_pt in (False, True):
                    self._copy(self.cache, jnp.asarray(0, jnp.int32),
                               jnp.asarray(0, jnp.int32), L=L,
                               set_pt=set_pt, b=jnp.asarray(0, jnp.int32),
                               idx=jnp.asarray(0, jnp.int32))
            self._sample(jnp.zeros((1, self.cfg.vocab_size)),
                         jnp.zeros((1,), jnp.int32),
                         jnp.zeros((1,), jnp.int32))
        else:
            dense = None
            for S in sorted({len(r.prompt) for r in requests}):
                logits, dense = self._prefill(
                    params, jnp.zeros((1, S), jnp.int32))
            if dense is not None:
                self._join(self.cache, dense, jnp.asarray(0, jnp.int32),
                           rows)
            self._sample(jnp.zeros((1, self.cfg.vocab_size)),
                         jnp.zeros((1,), jnp.int32),
                         jnp.zeros((1,), jnp.int32))
        self._evict(self.cache, jnp.asarray(0, jnp.int32))
        out, _ = self._decode(params, self.cache, jnp.asarray(self._tok),
                              jnp.asarray(self._pos),
                              jnp.asarray(self._rid),
                              jnp.asarray(self._ctr))
        jax.block_until_ready(out)

    # -- main loop --------------------------------------------------------

    def run(self, requests: list[Request],
            step_hook: Callable[[int], None] | None = None) -> ServeReport:
        """Serve ``requests`` to completion; returns the run report.

        ``step_hook(decode_step_index)`` fires after every decode step —
        the deterministic stand-in for a concurrent trainer (tests publish
        new weights from it).
        """
        reqs = sorted(requests, key=lambda r: r.arrival)
        if self.scfg.warmup:
            self._warmup(reqs)
        pending = deque(reqs)
        queue: deque[Request] = deque()
        self._finished = []
        finished = self._finished
        self._t0 = time.perf_counter()
        self._vnow = 0.0

        while len(finished) < len(reqs):
            now = self._now()
            while pending and pending[0].arrival <= now:
                queue.append(pending.popleft())
            n_left = len(pending) + len(queue)
            admitted = self._try_admit(queue, now, n_left)
            live = [b for b, s in enumerate(self.slots)
                    if s is not None and s.phase != "prefill"]
            if not live and not self._prefill_q:
                if not admitted and pending:
                    self._advance_to(pending[0].arrival)
                continue

            params = self.db.get()
            done_prefill = self._prefill_tick(params)
            did_decode = bool(live)
            if did_decode:
                self._cow_tick()
                toks, self.cache = self._decode(
                    params, self.cache, jnp.asarray(self._tok),
                    jnp.asarray(self._pos), jnp.asarray(self._rid),
                    jnp.asarray(self._ctr))
                toks = np.asarray(toks)
                self.decode_steps += 1
            if self.scfg.clock == "steps":
                self._vnow += 1.0
            now = self._now()
            if done_prefill is not None:
                self._activate_slot(done_prefill[0], done_prefill[1], now)
            if did_decode:
                for b in live:
                    s = self.slots[b]
                    self._live_slot_steps += 1
                    tk = int(toks[b])
                    self._pos[b] += 1
                    if s.phase == "tail":
                        p = int(self._pos[b])
                        if p < len(s.req.prompt):
                            self._tok[b, 0] = s.req.prompt[p]
                        else:          # tk is the first generated token
                            s.phase = "decode"
                            s.tokens = [tk]
                            s.remaining = s.req.gen_len - 1
                            s.t_first = now
                            self._tok[b, 0] = tk
                            self._ctr[b] = 1
                            self._insert_prefix(b)
                            if s.remaining <= 0:
                                self._retire(b, now)
                    else:
                        s.tokens.append(tk)
                        self._tok[b, 0] = tk
                        self._ctr[b] += 1
                        s.remaining -= 1
                        if s.remaining == 0:
                            self._retire(b, now)
                if step_hook is not None:
                    step_hook(self.decode_steps)

        duration = max(self._now(), 1e-9)
        lat = np.array([f.latency for f in finished])
        ttft = np.array([f.ttft for f in finished])
        total = sum(len(f.tokens) for f in finished)
        util = (self._live_slot_steps /
                (self.decode_steps * self.scfg.batch_size)
                if self.decode_steps else 0.0)
        return ServeReport(
            mode="continuous" if self.scfg.continuous else "static",
            n_requests=len(finished), total_tokens=total,
            duration=float(duration),
            tokens_per_sec=total / duration,
            latency_p50=float(np.percentile(lat, 50)),
            latency_p99=float(np.percentile(lat, 99)),
            ttft_p50=float(np.percentile(ttft, 50)),
            ttft_p99=float(np.percentile(ttft, 99)),
            decode_steps=self.decode_steps,
            prefill_chunks=self.prefill_chunks,
            prefix_hit_rate=(self.prefix.hit_rate if self.prefix else 0.0),
            utilization=util,
            outputs={f.rid: f.tokens for f in finished})
