"""Request-level serving engine: continuous batching over a live ParameterDB.

The engine owns ``batch_size`` sequence *slots* backed by one paged KV
cache (:mod:`repro.serve.paged_cache`).  Requests arrive on an open-loop
clock (:mod:`repro.serve.workload`); the scheduler joins a new sequence
the moment a slot frees up and evicts it the moment it finishes — decode
never drains the batch.  Every decode step runs the full (B,) batch with
per-sequence positions; idle slots sit at pos 0 with their page tables on
the junk page, so they cost one masked lane and touch no live state.

Parameters are never owned: each prefill and each decode step reads the
current tree from a :class:`repro.serve.live_db.LiveParamDB` (or
:class:`StaticParams`), so a trainer can publish new weights mid-serve
under the data-centric admissible-delay contract.

The classic static baseline is the same engine with ``continuous=False``:
admission only happens when every slot is free (and waits until a full
batch has arrived), which reintroduces the drain-the-batch barrier — the
difference between the two modes is purely scheduling policy, measured by
benchmarks/serve_bench.py.

Two clocks: ``"wall"`` (arrivals in seconds, ``time.perf_counter``) for
benchmarking, ``"steps"`` (arrivals in decode-step indices, a virtual
clock) for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from collections.abc import Mapping
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import decode_step, prefill
from .live_db import StaticParams
from .paged_cache import (PageAllocator, init_paged_cache, make_evict_fn,
                          make_join_fn)
from .workload import Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs (model architecture comes from ModelConfig)."""
    batch_size: int = 4          # sequence slots (B_max)
    page_size: int = 8           # tokens per KV page
    cache_len: int = 128         # logical ring length for full-attn layers
    continuous: bool = True      # False = static drain-the-batch baseline
    clock: str = "wall"          # "wall" (seconds) | "steps" (decode steps)
    warmup: bool = True          # compile before starting the clock

    def __post_init__(self):
        if self.clock not in ("wall", "steps"):
            raise ValueError(f"unknown clock {self.clock!r}")


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    arrival: float
    t_first: float               # clock at first token (end of prefill)
    t_done: float                # clock at last token
    tokens: tuple[int, ...]

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival


@dataclasses.dataclass
class ServeReport:
    mode: str                    # "continuous" | "static"
    n_requests: int
    total_tokens: int
    duration: float              # clock units (s or steps)
    tokens_per_sec: float        # tokens / duration (per-step for "steps")
    latency_p50: float
    latency_p99: float
    decode_steps: int
    utilization: float           # mean fraction of live slots per decode step
    outputs: dict[int, tuple[int, ...]]


class _Slot:
    __slots__ = ("req", "remaining", "tokens", "t_first")

    def __init__(self, req: Request, remaining: int, first_tok: int,
                 t_first: float):
        self.req = req
        self.remaining = remaining
        self.tokens = [first_tok]
        self.t_first = t_first


class ServeEngine:
    """One model, one paged cache, ``batch_size`` sequence slots."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        if cfg.frontend == "vision":
            raise NotImplementedError(
                "serving engine is text-only for now; vision archs need "
                "per-request media plumbing through admission and decode")
        self.cfg, self.scfg = cfg, scfg
        # a raw param pytree (a Mapping) gets the frozen handle; anything
        # else exposing get() is treated as a live handle (LiveParamDB)
        self.db = (StaticParams(params)
                   if isinstance(params, Mapping) or not hasattr(params, "get")
                   else params)
        B = scfg.batch_size
        self.alloc = PageAllocator(cfg, B, scfg.cache_len, scfg.page_size)
        self.cache = init_paged_cache(cfg, B, scfg.cache_len, scfg.page_size)
        self._join = jax.jit(make_join_fn(cfg, scfg.cache_len,
                                          scfg.page_size))
        self._evict = jax.jit(make_evict_fn(cfg, scfg.cache_len,
                                            scfg.page_size))
        self._prefill = jax.jit(lambda p, t: prefill(
            p, t, cfg, cache_len=scfg.cache_len))

        def _step(p, c, tok, pos):
            logits, c = decode_step(p, c, tok, pos, cfg)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), c

        self._decode = jax.jit(_step)
        self._tok = np.zeros((B, 1), np.int32)
        self._pos = np.zeros((B,), np.int32)
        self.slots: list[_Slot | None] = [None] * B
        self.decode_steps = 0
        self._live_slot_steps = 0

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        if self.scfg.clock == "wall":
            return time.perf_counter() - self._t0
        return self._vnow

    def _advance_to(self, t: float) -> None:
        """Idle fast-forward to the next arrival."""
        if self.scfg.clock == "wall":
            time.sleep(max(0.0, t - self._now()))
        else:
            self._vnow = max(self._vnow, t)

    # -- admission --------------------------------------------------------

    def _free_slot(self) -> int | None:
        for b, s in enumerate(self.slots):
            if s is None:
                return b
        return None

    def _admit(self, req: Request, now: float,
               finished: list[FinishedRequest]) -> None:
        params = self.db.get()
        tokens = jnp.asarray([req.prompt], jnp.int32)
        logits, dense = self._prefill(params, tokens)
        first = int(jnp.argmax(logits[0]))
        if req.gen_len <= 1:       # prompt-only request: done at prefill
            finished.append(FinishedRequest(
                req.rid, req.arrival, now, now, (first,)))
            return
        b = self._free_slot()
        assert b is not None, "admission with no free slot"
        rows = {L: jnp.asarray(ids) for L, ids in
                self.alloc.alloc(b).items()}
        self.cache = self._join(self.cache, dense,
                                jnp.asarray(b, jnp.int32), rows)
        self._tok[b, 0] = first
        self._pos[b] = len(req.prompt)
        self.slots[b] = _Slot(req, req.gen_len - 1, first, now)

    def _try_admit(self, queue: deque, now: float, n_left: int,
                   finished: list[FinishedRequest]) -> bool:
        admitted = False
        if self.scfg.continuous:
            while queue and self._free_slot() is not None:
                self._admit(queue.popleft(), now, finished)
                admitted = True
        else:
            # static baseline: wait for an empty engine AND a full batch
            # (or the tail of the workload), then admit the whole wave
            want = min(self.scfg.batch_size, n_left)
            if all(s is None for s in self.slots) and len(queue) >= want:
                for _ in range(want):
                    self._admit(queue.popleft(), now, finished)
                    admitted = True
        return admitted

    def _retire(self, b: int, now: float,
                finished: list[FinishedRequest]) -> None:
        s = self.slots[b]
        finished.append(FinishedRequest(
            s.req.rid, s.req.arrival, s.t_first, now, tuple(s.tokens)))
        self.cache = self._evict(self.cache, jnp.asarray(b, jnp.int32))
        self.alloc.free_slot(b)
        self._tok[b, 0] = 0
        self._pos[b] = 0
        self.slots[b] = None

    # -- warmup -----------------------------------------------------------

    def _warmup(self, requests: list[Request]) -> None:
        """Compile every shape the run will hit before the clock starts."""
        params = self.db.get()
        dense = None
        for S in sorted({len(r.prompt) for r in requests}):
            logits, dense = self._prefill(
                params, jnp.zeros((1, S), jnp.int32))
        if dense is not None:
            rows = {L: jnp.zeros((npp,), jnp.int32)
                    for L, npp in self.alloc.classes.items()}
            self._join(self.cache, dense, jnp.asarray(0, jnp.int32), rows)
        self._evict(self.cache, jnp.asarray(0, jnp.int32))
        out, _ = self._decode(params, self.cache, jnp.asarray(self._tok),
                              jnp.asarray(self._pos))
        jax.block_until_ready(out)

    # -- main loop --------------------------------------------------------

    def run(self, requests: list[Request],
            step_hook: Callable[[int], None] | None = None) -> ServeReport:
        """Serve ``requests`` to completion; returns the run report.

        ``step_hook(decode_step_index)`` fires after every decode step —
        the deterministic stand-in for a concurrent trainer (tests publish
        new weights from it).
        """
        reqs = sorted(requests, key=lambda r: r.arrival)
        if self.scfg.warmup:
            self._warmup(reqs)
        pending = deque(reqs)
        queue: deque[Request] = deque()
        finished: list[FinishedRequest] = []
        self._t0 = time.perf_counter()
        self._vnow = 0.0

        while len(finished) < len(reqs):
            now = self._now()
            while pending and pending[0].arrival <= now:
                queue.append(pending.popleft())
            n_left = len(pending) + len(queue)
            admitted = self._try_admit(queue, now, n_left, finished)
            if all(s is None for s in self.slots):
                if not admitted and pending:
                    self._advance_to(pending[0].arrival)
                continue

            params = self.db.get()
            toks, self.cache = self._decode(
                params, self.cache, jnp.asarray(self._tok),
                jnp.asarray(self._pos))
            toks = np.asarray(toks)
            self.decode_steps += 1
            if self.scfg.clock == "steps":
                self._vnow += 1.0
            now = self._now()
            for b, s in enumerate(self.slots):
                if s is None:
                    continue
                self._live_slot_steps += 1
                s.tokens.append(int(toks[b]))
                self._tok[b, 0] = int(toks[b])
                self._pos[b] += 1
                s.remaining -= 1
                if s.remaining == 0:
                    self._retire(b, now, finished)
            if step_hook is not None:
                step_hook(self.decode_steps)

        duration = max(self._now(), 1e-9)
        lat = np.array([f.latency for f in finished])
        total = sum(len(f.tokens) for f in finished)
        util = (self._live_slot_steps /
                (self.decode_steps * self.scfg.batch_size)
                if self.decode_steps else 0.0)
        return ServeReport(
            mode="continuous" if self.scfg.continuous else "static",
            n_requests=len(finished), total_tokens=total,
            duration=float(duration),
            tokens_per_sec=total / duration,
            latency_p50=float(np.percentile(lat, 50)),
            latency_p99=float(np.percentile(lat, 99)),
            decode_steps=self.decode_steps,
            utilization=util,
            outputs={f.rid: f.tokens for f in finished})
