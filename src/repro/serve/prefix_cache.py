"""Prompt-prefix cache: a radix trie over token IDs at page granularity.

Requests in real serving traffic share long prompt prefixes (system
prompts, few-shot preambles).  Once one request has computed a prefix's
K/V pages, later requests can *adopt* those physical pages instead of
recomputing them — the trie maps page-sized token runs to the physical
page ids (one per page class) holding their K/V.

Granularity:

* **Nodes are one page of tokens** (``page_size`` ids).  A node's pages
  are only ever inserted from a slot whose whole prompt fit inside the
  smallest page-class ring (no wrap), so each physical page holds pure
  positional content for exactly those tokens in every class.
* **Adoption is token-granular.**  A full-node match shares the page
  read-only (refcount on both the node and, per class, the page).  A
  *partial* match — the prompt diverges mid-page, or the whole prompt is
  cached and the last token must be recomputed for its logits — adopts a
  private *copy* of that page and overwrites from the divergence point:
  copy-on-write at the adoption boundary.  Stale donor tokens past the
  match sit at ring slots ahead of the adopter's position, which the
  decode mask (``models.attention._ring_valid``) reconstructs as dead,
  so a partially matched page never needs scrubbing.

Eviction is LRU over refcount-zero *leaf* nodes (interior nodes become
leaves as their children go), wired into the :class:`PageAllocator`
free list: ``evict_for`` frees nodes until an allocation can proceed, so
the trie soaks up all pool headroom and gives it back under pressure.

The trie itself is pure host-side bookkeeping; the engine issues the
device-side page copies.
"""
from __future__ import annotations

import itertools
from typing import Iterable

import numpy as np

from .paged_cache import PageAllocator


class _Node:
    __slots__ = ("key", "pages", "parent", "children", "ref", "last_used")

    def __init__(self, key: tuple, pages: dict, parent: "_Node | None"):
        self.key = key                    # page_size token ids
        self.pages = pages                # {L: physical page id}
        self.parent = parent
        self.children: dict[tuple, _Node] = {}
        self.ref = 0                      # live adopters (eviction guard)
        self.last_used = 0


class PrefixCache:
    """Radix trie of cached prompt prefixes over a shared page pool."""

    def __init__(self, alloc: PageAllocator, page_size: int):
        self.alloc = alloc
        self.page = page_size
        self.root = _Node((), {}, None)
        self._clock = itertools.count(1)
        # stats
        self.lookups = 0
        self.hits = 0                     # lookups that adopted >= 1 token
        self.tokens_hit = 0
        self.tokens_seen = 0

    # -- internals --------------------------------------------------------

    def _touch(self, node: _Node) -> None:
        node.last_used = next(self._clock)

    def _chunks(self, prompt) -> list[tuple]:
        p = self.page
        return [tuple(int(t) for t in prompt[i:i + p])
                for i in range(0, len(prompt) - len(prompt) % p, p)]

    # -- lookup / lease ---------------------------------------------------

    def lookup(self, prompt) -> tuple[list[_Node], tuple[_Node, int] | None]:
        """Longest cached prefix of ``prompt``, capped at ``len - 1``
        tokens (the last prompt token is always recomputed — its logits
        are not cached).  Returns ``(full_nodes, partial)``: nodes whose
        whole page is adopted shared, plus an optional ``(node, t)``
        tail whose first ``t`` (< page_size) tokens match and whose page
        the engine must adopt by copy."""
        self.lookups += 1
        self.tokens_seen += len(prompt)
        max_adopt = len(prompt) - 1
        full: list[_Node] = []
        node = self.root
        matched = 0
        partial: tuple[_Node, int] | None = None
        for key in self._chunks(prompt):
            child = node.children.get(key)
            if child is not None and matched + self.page <= max_adopt:
                full.append(child)
                node = child
                matched += self.page
                continue
            # divergence (or cap): find the child sharing the longest
            # proper token prefix of this page
            best, best_t = None, 0
            cap = min(self.page, max_adopt - matched)
            cand = [child] if child is not None else node.children.values()
            for c in cand:
                t = 0
                for a, btok in zip(c.key, key):
                    if a != btok or t >= cap:
                        break
                    t += 1
                if t > best_t:
                    best, best_t = c, t
            if best is not None:
                partial = (best, best_t)
                matched += best_t
            break
        if matched:
            self.hits += 1
            self.tokens_hit += matched
        return full, partial

    def lease(self, nodes: Iterable[_Node]) -> None:
        """Take one reference on each node (eviction guard) and, per page
        class, on its physical page.  The page references are the
        adopter's — they are dropped through ``PageAllocator.free_slot``
        once the ids sit in the slot's table; node references are
        dropped with :meth:`release`."""
        for node in nodes:
            node.ref += 1
            for L, pid in node.pages.items():
                self.alloc.incref(L, pid)
            self._touch(node)

    def release(self, nodes: Iterable[_Node],
                drop_pages: bool = False) -> None:
        """Drop node references taken by :meth:`lease` (or by
        :meth:`insert` for newly created nodes).  ``drop_pages`` also
        drops the per-class page references — only for leases whose ids
        never made it into a slot table (the transient guard around an
        admission-time partial-page copy)."""
        for node in nodes:
            node.ref -= 1
            assert node.ref >= 0, "prefix node over-released"
            if drop_pages:
                for L, pid in node.pages.items():
                    self.alloc.decref(L, pid)

    # -- insert -----------------------------------------------------------

    def insert(self, prompt, rows: dict[int, np.ndarray]
               ) -> tuple[list[_Node], list[int]]:
        """Publish a freshly prefilled prompt's full pages into the trie.
        ``rows``: the slot's physical page rows per class.  Only whole
        pages strictly before the page the slot writes next are shared
        (a trailing partial page stays private).  Every node on the path
        gets one ``ref`` held by the inserting slot (release at retire);
        newly created nodes additionally take a trie-owned reference on
        the slot's physical pages.  Returns ``(path_nodes,
        new_logical_idx)`` — the logical page indices that are now
        shared and must be copy-on-write protected for this slot."""
        node = self.root
        path: list[_Node] = []
        new_idx: list[int] = []
        for i, key in enumerate(self._chunks(prompt)):
            child = node.children.get(key)
            if child is None:
                pages = {L: int(r[i]) for L, r in rows.items()}
                child = _Node(key, pages, node)
                node.children[key] = child
                for L, pid in pages.items():
                    self.alloc.incref(L, pid)
                new_idx.append(i)
            child.ref += 1
            self._touch(child)
            path.append(child)
            node = child
        return path, new_idx

    # -- eviction ---------------------------------------------------------

    def _evictable(self) -> _Node | None:
        best = None
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is self.root or n.children or n.ref > 0:
                continue
            if best is None or n.last_used < best.last_used:
                best = n
        return best

    def evict_one(self) -> bool:
        """Drop the least-recently-used unreferenced leaf, returning its
        pages' trie references (pages still shared with a live slot stay
        allocated until that slot frees them)."""
        node = self._evictable()
        if node is None:
            return False
        del node.parent.children[node.key]
        for L, pid in node.pages.items():
            self.alloc.decref(L, pid)
        return True

    def evict_for(self, L: int, need: int) -> None:
        """Evict until class ``L`` has ``need`` free pages (or nothing is
        evictable — the subsequent allocation then fails loudly)."""
        while self.alloc.n_free(L) < need and self.evict_one():
            pass

    # -- stats ------------------------------------------------------------

    @property
    def n_nodes(self) -> int:
        n, stack = 0, [self.root]
        while stack:
            cur = stack.pop()
            n += len(cur.children)
            stack.extend(cur.children.values())
        return n

    @property
    def hit_rate(self) -> float:
        """Fraction of prompt tokens served from the cache."""
        return self.tokens_hit / self.tokens_seen if self.tokens_seen else 0.0
