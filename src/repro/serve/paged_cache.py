"""Paged per-sequence decode caches: fixed-size pages + a free-list allocator.

The serving engine holds ``batch`` sequence *slots*.  Dense decode caches
would give each slot a private (L, KV, hd) ring per attention layer; here
every attention layer instead shares one pool of fixed-size pages, and each
slot owns a page table mapping its logical ring pages to physical pool
pages.  Joining a sequence allocates pages from a free list and scatters
its prefilled ring into them; evicting returns the pages.  The logical
view (``slot = pos % L``) is exactly the dense ring, so the existing
ring-slot masked decode-attention kernel runs unchanged on the gathered
view (models/attention.py ``attention_decode_paged`` +
kernels/page_gather.py).

Layers with the same logical length L form one *page class* (full-context
``attn`` layers vs windowed ``local``/``swa`` rings); all layers of a class
share one page-table per slot, so the allocator hands out one row of page
ids per (slot, class).  Each class pool reserves one extra *junk page*:
freed slots' page tables point at it, so the unconditional per-step KV
write of an idle batch row lands in the junk page and can never corrupt a
live sequence's pages.

Recurrent state (rwkv6 / rglru) is O(1) per sequence and stays a dense
``batch``-row array — "paging" it would be indirection for nothing; join
simply overwrites row ``b``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models import attention as attn_mod
from ..models import rglru as rglru_mod
from ..models import rwkv6 as rwkv_mod
from ..models.config import ModelConfig

ATTN_KINDS = ("attn", "local", "swa")


def page_classes(cfg: ModelConfig, cache_len: int,
                 page_size: int) -> dict[int, int]:
    """{logical length L: pages per sequence} over the model's attention
    kinds.  Every L must be a multiple of ``page_size`` so the ring
    modulus is preserved across the page boundary."""
    classes: dict[int, int] = {}
    for kind in set(cfg.layer_kinds):
        if kind not in ATTN_KINDS:
            continue
        L = cfg.kv_cache_len(kind, cache_len)
        if L % page_size != 0:
            raise ValueError(
                f"page_size {page_size} must divide cache length {L} "
                f"(kind {kind!r}; pick cache_len/window multiples of it)")
        classes[L] = L // page_size
    return classes


class PageAllocator:
    """Refcounted free-list page allocator over one engine's page classes.

    Pure host-side bookkeeping: physical page ids live in numpy tables;
    the device-side copies inside the cache pytree are written by the
    jitted join/evict functions below.  Pool capacity per class is
    ``(batch + extra_seqs) * pages_per_seq + 1`` (the +1 is the junk
    page, id ``P - 1``) — with the default ``extra_seqs=0``, allocation
    succeeds iff a sequence slot is free; the extra headroom holds the
    prefix cache's retained pages (repro.serve.prefix_cache) and the
    transient copy-on-write duplicates.

    Pages are refcounted so they can be *shared*: a slot adopting a
    cached prefix and the prefix trie holding it each own one reference
    (``incref``/``decref``); a page returns to the free list only when
    its last owner drops it.  ``alloc``/``free_slot`` keep the PR-5
    whole-slot semantics on top: a freshly allocated page is born with
    one reference owned through the slot's table row, and ``free_slot``
    drops one reference per table entry.
    """

    def __init__(self, cfg: ModelConfig, batch: int, cache_len: int,
                 page_size: int, extra_seqs: int = 0):
        self.batch = batch
        self.page_size = page_size
        self.classes = page_classes(cfg, cache_len, page_size)
        cap = {L: (batch + extra_seqs) * npp
               for L, npp in self.classes.items()}
        self.junk = dict(cap)
        self.free: dict[int, list[int]] = {
            L: list(range(n)) for L, n in cap.items()}
        self.refcount: dict[int, np.ndarray] = {
            L: np.zeros(n, np.int32) for L, n in cap.items()}
        self.tables: dict[int, np.ndarray] = {
            L: np.full((batch, npp), self.junk[L], np.int32)
            for L, npp in self.classes.items()}

    def n_free(self, L: int) -> int:
        return len(self.free[L])

    def alloc_pages(self, L: int, k: int) -> np.ndarray:
        """Pop ``k`` pages of class ``L`` off the free list (each born
        with refcount 1, owned by the caller)."""
        if len(self.free[L]) < k:
            raise RuntimeError(f"page pool exhausted (L={L})")
        ids = np.array([self.free[L].pop() for _ in range(k)], np.int32)
        self.refcount[L][ids] = 1
        return ids

    def incref(self, L: int, ids) -> None:
        for p in np.atleast_1d(np.asarray(ids, np.int64)):
            self.refcount[L][p] += 1

    def decref(self, L: int, ids) -> None:
        for p in np.atleast_1d(np.asarray(ids, np.int64)):
            self.refcount[L][p] -= 1
            if self.refcount[L][p] == 0:
                self.free[L].append(int(p))
            assert self.refcount[L][p] >= 0, f"page {p} over-freed (L={L})"

    def install(self, b: int, rows: dict[int, np.ndarray]) -> None:
        """Record slot ``b``'s page-id rows (caller already owns one
        reference per page, e.g. via alloc_pages/incref)."""
        for L, ids in rows.items():
            if (self.tables[L][b] != self.junk[L]).any():
                raise ValueError(f"slot {b} already holds pages (L={L})")
            self.tables[L][b] = np.asarray(ids, np.int32)

    def alloc(self, b: int) -> dict[int, np.ndarray]:
        """Allocate slot ``b``'s pages in every class; returns the page-id
        rows ({L: (n_pp,) int32}) to hand to the jitted join."""
        rows = {}
        for L, npp in self.classes.items():
            if (self.tables[L][b] != self.junk[L]).any():
                raise ValueError(f"slot {b} already holds pages (L={L})")
            if len(self.free[L]) < npp:
                raise RuntimeError(f"page pool exhausted (L={L})")
            rows[L] = self.alloc_pages(L, npp)
            self.tables[L][b] = rows[L]
        return rows

    def free_slot(self, b: int) -> None:
        """Drop slot ``b``'s reference on each of its pages (a page whose
        last reference this was returns to the free list); the table row
        goes back to the junk page."""
        for L in self.classes:
            row = self.tables[L][b]
            live = row[row != self.junk[L]]
            self.decref(L, live)
            self.tables[L][b] = self.junk[L]


def _walk_slots(cfg: ModelConfig):
    for gi, g in enumerate(cfg.groups):
        for si, kind in enumerate(g.pattern):
            yield f"g{gi}", f"s{si}", kind, g.n


def init_paged_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     page_size: int, extra_seqs: int = 0) -> dict:
    """Paged analogue of ``transformer.init_cache``: attention slots get
    {"pk", "pv": (stack, P, page, KV, hd) pools, "pt": (stack, B, n_pp)
    tables} (tables start at the junk page, id ``P - 1``); recurrent
    slots keep their dense per-row state.  ``extra_seqs`` adds that many
    sequences' worth of pool headroom per class for the prefix cache and
    copy-on-write duplicates (must match the PageAllocator's)."""
    classes = page_classes(cfg, cache_len, page_size)
    cache: dict[str, Any] = {}
    for gkey, skey, kind, n in _walk_slots(cfg):
        slots = cache.setdefault(gkey, {})
        stack = (n,)
        if kind in ATTN_KINDS:
            L = cfg.kv_cache_len(kind, cache_len)
            npp = classes[L]
            P = (batch + extra_seqs) * npp + 1
            pool = jnp.zeros(stack + (P, page_size, cfg.n_kv_heads, cfg.hd),
                             cfg.dtype)
            pt = jnp.full(stack + (batch, npp), P - 1, jnp.int32)
            slots[skey] = {"pk": pool, "pv": pool, "pt": pt}
        elif kind == "rwkv6":
            slots[skey] = rwkv_mod.init_rwkv_state(cfg, batch, stack)
        elif kind == "rglru":
            slots[skey] = rglru_mod.init_rglru_state(cfg, batch, stack)
        else:                       # xattn: stateless
            slots[skey] = {}
    return cache


def make_join_fn(cfg: ModelConfig, cache_len: int,
                 page_size: int) -> Callable:
    """Build ``join(cache, dense, b, rows) -> cache``: scatter one
    sequence's dense prefill cache (``prefill(..., cache_len)`` with
    B=1) into paged slot ``b``.  ``rows``: {L: (n_pp,) int32 page ids}
    from ``PageAllocator.alloc``.  Jit-able: one compilation per engine
    (dense cache shape depends only on cache_len)."""

    def join(cache: dict, dense: dict, b: jnp.ndarray,
             rows: dict[int, jnp.ndarray]) -> dict:
        new = {}
        for gkey, skey, kind, n in _walk_slots(cfg):
            slots = new.setdefault(gkey, {})
            pc, dc = cache[gkey][skey], dense[gkey][skey]
            if kind in ATTN_KINDS:
                L = cfg.kv_cache_len(kind, cache_len)
                ids = rows[L]
                npp = ids.shape[0]
                dk = dc["k"][:, 0].reshape(n, npp, page_size,
                                           cfg.n_kv_heads, cfg.hd)
                dv = dc["v"][:, 0].reshape(n, npp, page_size,
                                           cfg.n_kv_heads, cfg.hd)
                slots[skey] = {
                    "pk": pc["pk"].at[:, ids].set(dk.astype(pc["pk"].dtype)),
                    "pv": pc["pv"].at[:, ids].set(dv.astype(pc["pv"].dtype)),
                    "pt": pc["pt"].at[:, b].set(ids),
                }
            elif kind in ("rwkv6", "rglru"):
                slots[skey] = jax.tree.map(
                    lambda p, d: p.at[:, b].set(d[:, 0].astype(p.dtype)),
                    pc, dc)
            else:
                slots[skey] = pc
        return new

    return join


def make_evict_fn(cfg: ModelConfig, cache_len: int,
                  page_size: int) -> Callable:
    """Build ``evict(cache, b) -> cache``: point slot ``b``'s page tables
    back at the junk page (page data needs no clearing — a later join
    overwrites every page it allocates, and junk-pointing tables keep the
    idle row's per-step KV write off live pages)."""
    classes = page_classes(cfg, cache_len, page_size)

    def evict(cache: dict, b: jnp.ndarray) -> dict:
        new = {}
        for gkey, skey, kind, n in _walk_slots(cfg):
            slots = new.setdefault(gkey, {})
            pc = cache[gkey][skey]
            if kind in ATTN_KINDS:
                L = cfg.kv_cache_len(kind, cache_len)
                npp = classes[L]
                junk = pc["pk"].shape[1] - 1      # junk page id is P - 1
                junk_row = jnp.full((npp,), junk, jnp.int32)
                slots[skey] = {**pc, "pt": pc["pt"].at[:, b].set(junk_row)}
            else:
                slots[skey] = pc
        return new

    return evict


def make_activate_fn(cfg: ModelConfig, cache_len: int,
                     page_size: int) -> Callable:
    """Build ``activate(cache, b, rows, carry) -> cache``: flip a slot
    from prefilling to live.  Sets slot ``b``'s page-table rows to its
    physical pages (``rows``: {L: (n_pp,) int32}) and writes the chunked
    prefill's recurrent carry (``transformer.init_chunk_carry`` pytree,
    B=1) into the dense recurrent rows.  Until this runs, the slot's
    tables sit on the junk page and its recurrent rows are dead, so the
    interleaved decode steps of other slots can't corrupt an in-flight
    prefill."""

    def activate(cache: dict, b: jnp.ndarray, rows: dict,
                 carry: dict) -> dict:
        new = {}
        for gkey, skey, kind, n in _walk_slots(cfg):
            slots = new.setdefault(gkey, {})
            pc = cache[gkey][skey]
            if kind in ATTN_KINDS:
                L = cfg.kv_cache_len(kind, cache_len)
                slots[skey] = {**pc, "pt": pc["pt"].at[:, b].set(rows[L])}
            elif kind in ("rwkv6", "rglru"):
                car = carry[gkey][skey]
                slots[skey] = jax.tree.map(
                    lambda p, d: p.at[:, b].set(d[:, 0].astype(p.dtype)),
                    pc, car)
            else:
                slots[skey] = pc
        return new

    return activate


def make_copy_page_fn(cfg: ModelConfig, cache_len: int,
                      page_size: int) -> Callable:
    """Build ``copy_page(cache, src, dst, L, set_pt, b, idx) -> cache``:
    duplicate physical page ``src`` into ``dst`` across every attention
    layer of page class ``L`` (``L``/``set_pt`` static for jit).  With
    ``set_pt`` the slot's page-table entry ``idx`` is repointed at the
    copy in the same call — the copy-on-write step when a live slot is
    about to overwrite a page it shares with the prefix cache.  Without
    it only the pools change (admission-time copy of a partially matched
    prefix page: the slot's device table is still on the junk page)."""

    def copy_page(cache: dict, src: jnp.ndarray, dst: jnp.ndarray,
                  L: int, set_pt: bool, b: jnp.ndarray,
                  idx: jnp.ndarray) -> dict:
        new = {}
        for gkey, skey, kind, n in _walk_slots(cfg):
            slots = new.setdefault(gkey, {})
            pc = cache[gkey][skey]
            if kind in ATTN_KINDS and cfg.kv_cache_len(kind, cache_len) == L:
                pk = pc["pk"].at[:, dst].set(pc["pk"][:, src])
                pv = pc["pv"].at[:, dst].set(pc["pv"][:, src])
                pt = pc["pt"].at[:, b, idx].set(dst.astype(jnp.int32)) \
                    if set_pt else pc["pt"]
                slots[skey] = {"pk": pk, "pv": pv, "pt": pt}
            else:
                slots[skey] = pc
        return new

    return copy_page
