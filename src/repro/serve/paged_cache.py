"""Paged per-sequence decode caches: fixed-size pages + a free-list allocator.

The serving engine holds ``batch`` sequence *slots*.  Dense decode caches
would give each slot a private (L, KV, hd) ring per attention layer; here
every attention layer instead shares one pool of fixed-size pages, and each
slot owns a page table mapping its logical ring pages to physical pool
pages.  Joining a sequence allocates pages from a free list and scatters
its prefilled ring into them; evicting returns the pages.  The logical
view (``slot = pos % L``) is exactly the dense ring, so the existing
ring-slot masked decode-attention kernel runs unchanged on the gathered
view (models/attention.py ``attention_decode_paged`` +
kernels/page_gather.py).

Layers with the same logical length L form one *page class* (full-context
``attn`` layers vs windowed ``local``/``swa`` rings); all layers of a class
share one page-table per slot, so the allocator hands out one row of page
ids per (slot, class).  Each class pool reserves one extra *junk page*:
freed slots' page tables point at it, so the unconditional per-step KV
write of an idle batch row lands in the junk page and can never corrupt a
live sequence's pages.

Recurrent state (rwkv6 / rglru) is O(1) per sequence and stays a dense
``batch``-row array — "paging" it would be indirection for nothing; join
simply overwrites row ``b``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models import attention as attn_mod
from ..models import rglru as rglru_mod
from ..models import rwkv6 as rwkv_mod
from ..models.config import ModelConfig

ATTN_KINDS = ("attn", "local", "swa")


def page_classes(cfg: ModelConfig, cache_len: int,
                 page_size: int) -> dict[int, int]:
    """{logical length L: pages per sequence} over the model's attention
    kinds.  Every L must be a multiple of ``page_size`` so the ring
    modulus is preserved across the page boundary."""
    classes: dict[int, int] = {}
    for kind in set(cfg.layer_kinds):
        if kind not in ATTN_KINDS:
            continue
        L = cfg.kv_cache_len(kind, cache_len)
        if L % page_size != 0:
            raise ValueError(
                f"page_size {page_size} must divide cache length {L} "
                f"(kind {kind!r}; pick cache_len/window multiples of it)")
        classes[L] = L // page_size
    return classes


class PageAllocator:
    """Free-list page allocator over the page classes of one engine.

    Pure host-side bookkeeping: physical page ids live in numpy tables;
    the device-side copies inside the cache pytree are written by the
    jitted join/evict functions below.  Pool capacity is
    ``batch * pages_per_seq + 1`` per class (the +1 is the junk page, id
    ``P - 1``), so allocation succeeds iff a sequence slot is free.
    """

    def __init__(self, cfg: ModelConfig, batch: int, cache_len: int,
                 page_size: int):
        self.batch = batch
        self.page_size = page_size
        self.classes = page_classes(cfg, cache_len, page_size)
        self.junk = {L: batch * npp for L, npp in self.classes.items()}
        self.free: dict[int, list[int]] = {
            L: list(range(batch * npp)) for L, npp in self.classes.items()}
        self.tables: dict[int, np.ndarray] = {
            L: np.full((batch, npp), self.junk[L], np.int32)
            for L, npp in self.classes.items()}

    def n_free(self, L: int) -> int:
        return len(self.free[L])

    def alloc(self, b: int) -> dict[int, np.ndarray]:
        """Allocate slot ``b``'s pages in every class; returns the page-id
        rows ({L: (n_pp,) int32}) to hand to the jitted join."""
        rows = {}
        for L, npp in self.classes.items():
            if (self.tables[L][b] != self.junk[L]).any():
                raise ValueError(f"slot {b} already holds pages (L={L})")
            if len(self.free[L]) < npp:
                raise RuntimeError(f"page pool exhausted (L={L})")
            ids = np.array([self.free[L].pop() for _ in range(npp)],
                           np.int32)
            self.tables[L][b] = ids
            rows[L] = ids
        return rows

    def free_slot(self, b: int) -> None:
        """Return slot ``b``'s pages to the free lists; its table row goes
        back to the junk page."""
        for L in self.classes:
            row = self.tables[L][b]
            live = row[row != self.junk[L]]
            self.free[L].extend(int(p) for p in live)
            self.tables[L][b] = self.junk[L]


def _walk_slots(cfg: ModelConfig):
    for gi, g in enumerate(cfg.groups):
        for si, kind in enumerate(g.pattern):
            yield f"g{gi}", f"s{si}", kind, g.n


def init_paged_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     page_size: int) -> dict:
    """Paged analogue of ``transformer.init_cache``: attention slots get
    {"pk", "pv": (stack, P, page, KV, hd) pools, "pt": (stack, B, n_pp)
    tables} (tables start at the junk page); recurrent slots keep their
    dense per-row state."""
    classes = page_classes(cfg, cache_len, page_size)
    cache: dict[str, Any] = {}
    for gkey, skey, kind, n in _walk_slots(cfg):
        slots = cache.setdefault(gkey, {})
        stack = (n,)
        if kind in ATTN_KINDS:
            L = cfg.kv_cache_len(kind, cache_len)
            npp = classes[L]
            P = batch * npp + 1
            pool = jnp.zeros(stack + (P, page_size, cfg.n_kv_heads, cfg.hd),
                             cfg.dtype)
            pt = jnp.full(stack + (batch, npp), batch * npp, jnp.int32)
            slots[skey] = {"pk": pool, "pv": pool, "pt": pt}
        elif kind == "rwkv6":
            slots[skey] = rwkv_mod.init_rwkv_state(cfg, batch, stack)
        elif kind == "rglru":
            slots[skey] = rglru_mod.init_rglru_state(cfg, batch, stack)
        else:                       # xattn: stateless
            slots[skey] = {}
    return cache


def make_join_fn(cfg: ModelConfig, cache_len: int,
                 page_size: int) -> Callable:
    """Build ``join(cache, dense, b, rows) -> cache``: scatter one
    sequence's dense prefill cache (``prefill(..., cache_len)`` with
    B=1) into paged slot ``b``.  ``rows``: {L: (n_pp,) int32 page ids}
    from ``PageAllocator.alloc``.  Jit-able: one compilation per engine
    (dense cache shape depends only on cache_len)."""

    def join(cache: dict, dense: dict, b: jnp.ndarray,
             rows: dict[int, jnp.ndarray]) -> dict:
        new = {}
        for gkey, skey, kind, n in _walk_slots(cfg):
            slots = new.setdefault(gkey, {})
            pc, dc = cache[gkey][skey], dense[gkey][skey]
            if kind in ATTN_KINDS:
                L = cfg.kv_cache_len(kind, cache_len)
                ids = rows[L]
                npp = ids.shape[0]
                dk = dc["k"][:, 0].reshape(n, npp, page_size,
                                           cfg.n_kv_heads, cfg.hd)
                dv = dc["v"][:, 0].reshape(n, npp, page_size,
                                           cfg.n_kv_heads, cfg.hd)
                slots[skey] = {
                    "pk": pc["pk"].at[:, ids].set(dk.astype(pc["pk"].dtype)),
                    "pv": pc["pv"].at[:, ids].set(dv.astype(pc["pv"].dtype)),
                    "pt": pc["pt"].at[:, b].set(ids),
                }
            elif kind in ("rwkv6", "rglru"):
                slots[skey] = jax.tree.map(
                    lambda p, d: p.at[:, b].set(d[:, 0].astype(p.dtype)),
                    pc, dc)
            else:
                slots[skey] = pc
        return new

    return join


def make_evict_fn(cfg: ModelConfig, cache_len: int,
                  page_size: int) -> Callable:
    """Build ``evict(cache, b) -> cache``: point slot ``b``'s page tables
    back at the junk page (page data needs no clearing — a later join
    overwrites every page it allocates, and junk-pointing tables keep the
    idle row's per-step KV write off live pages)."""
    classes = page_classes(cfg, cache_len, page_size)

    def evict(cache: dict, b: jnp.ndarray) -> dict:
        new = {}
        for gkey, skey, kind, n in _walk_slots(cfg):
            slots = new.setdefault(gkey, {})
            pc = cache[gkey][skey]
            if kind in ATTN_KINDS:
                L = cfg.kv_cache_len(kind, cache_len)
                npp = classes[L]
                batch = pc["pt"].shape[1]
                junk_row = jnp.full((npp,), batch * npp, jnp.int32)
                slots[skey] = {**pc, "pt": pc["pt"].at[:, b].set(junk_row)}
            else:
                slots[skey] = pc
        return new

    return evict
