"""Live parameter reads for serving: bounded-stale gets over a ParameterDB.

The serving engine never owns its weights.  It holds a handle whose
``get()`` returns the current parameter tree; two implementations:

  * :class:`StaticParams` — frozen weights (plain serving, no trainer);
  * :class:`LiveParamDB` — a trainer publishes new weights while the
    server reads, with the data-centric admissible-delay contract (paper
    Sec 7) applied per parameter group: leaves are grouped by their
    resolved ``SyncConfig.delay_for`` delay, and a group's served copy is
    refreshed only once its staleness would exceed the group's delay.
    Every access is recorded as an :class:`repro.core.history.Op` in a
    shared :class:`repro.pdb.telemetry.Telemetry` (trainer = worker 0,
    server = worker 1, chunk = delay group), so
    ``history.is_sequentially_correct`` remains the one semantic oracle
    and tests can assert the per-read staleness bound from the log.

Versioning convention matches the rest of the repo: ``publish(params,
itr)`` installs the weights produced by training iteration ``itr``
(1-based); version 0 is the initial tree.  A server read while ``itr``
iterations have completed is an op of the in-progress iteration
``alpha = itr + 1``, observing some version ``v <= itr`` with staleness
``(alpha - 1) - v = itr - v`` — the same formula Telemetry applies.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

import jax

from ..core.sync_jax import SyncConfig
from ..pdb.telemetry import Telemetry

PyTree = Any


class StaticParams:
    """Frozen-weight handle: ``get()`` always returns the same tree."""

    def __init__(self, params: PyTree):
        self._params = params

    def get(self) -> PyTree:
        return self._params


@dataclasses.dataclass(frozen=True)
class ReadRecord:
    """One server-side group read (the test hook for the delay bound)."""
    chunk: int          # delay-group index
    delay: int          # the group's admissible delay d_g
    itr: int            # alpha: in-progress iteration at read time
    version: int        # published version the read observed
    @property
    def staleness(self) -> int:
        return (self.itr - 1) - self.version


class _Group:
    def __init__(self, chunk: int, delay: int, idxs: list[int]):
        self.chunk, self.delay, self.idxs = chunk, delay, idxs


class LiveParamDB:
    """Serve-while-train parameter handle with per-group admissible delays.

    ``publish`` (trainer side) swaps in the full tree; ``get`` (server
    side) rebuilds its view group by group, keeping a group's previous
    copy as long as its staleness stays within ``delay_for`` and
    refreshing it from the latest publish the moment it would not.  Both
    run under one lock, so each call is atomic against the other and the
    recorded Op history is a real total order.
    """

    def __init__(self, params: PyTree, sync: SyncConfig,
                 telemetry: Telemetry | None = None):
        self.sync = sync
        self.telemetry = telemetry or Telemetry(record_history=True)
        self._lock = threading.Lock()
        leaves = jax.tree_util.tree_leaves_with_path(params)
        self._treedef = jax.tree_util.tree_structure(params)
        by_delay: dict[int, list[int]] = {}
        for i, (path, _) in enumerate(leaves):
            by_delay.setdefault(sync.delay_for(path), []).append(i)
        self._groups = [_Group(chunk, d, by_delay[d])
                        for chunk, d in enumerate(sorted(by_delay))]
        self._latest = [leaf for _, leaf in leaves]
        self._version = 0
        self._cached = list(self._latest)
        self._cached_version = [0] * len(self._groups)
        self.read_log: list[ReadRecord] = []

    @property
    def n_chunks(self) -> int:
        """Chunk count for ``is_sequentially_correct(history, n_chunks)``."""
        return len(self._groups)

    @property
    def version(self) -> int:
        return self._version

    def publish(self, params: PyTree, itr: int) -> None:
        """Install the weights produced by training iteration ``itr``.

        Records the trainer's Def-3 program for the iteration: read every
        group (it read version ``itr - 1`` to compute the update), then
        write every group.
        """
        leaves = jax.tree_util.tree_leaves(params)
        with self._lock:
            if itr != self._version + 1:
                raise ValueError(
                    f"publish({itr}) out of order; last was {self._version}")
            for g in self._groups:
                self.telemetry.on_read(0, g.chunk, itr, version=itr - 1)
            self._latest = leaves
            self._version = itr
            for g in self._groups:
                self.telemetry.on_write(0, g.chunk, itr)

    def get(self) -> PyTree:
        """The server's view: per group, the cached copy while it is
        admissibly stale, else a refresh to the latest publish."""
        with self._lock:
            itr = self._version
            alpha = itr + 1
            for g in self._groups:
                v = self._cached_version[g.chunk]
                if itr - v > g.delay:
                    for i in g.idxs:
                        self._cached[i] = self._latest[i]
                    v = itr
                    self._cached_version[g.chunk] = v
                self.telemetry.on_read(1, g.chunk, alpha, version=v)
                self.read_log.append(
                    ReadRecord(chunk=g.chunk, delay=g.delay,
                               itr=alpha, version=v))
            return jax.tree_util.tree_unflatten(self._treedef,
                                                list(self._cached))
