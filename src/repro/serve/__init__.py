"""Request-level serving: continuous batching over a live ParameterDB.

Public surface:

  * :class:`ServeEngine` / :class:`ServeConfig` — the engine (engine.py)
  * :func:`open_loop_requests` / :func:`shared_prefix_requests` /
    :class:`Request` — workload (workload.py)
  * :class:`LiveParamDB` / :class:`StaticParams` — parameter handles
    (live_db.py)
  * :class:`PrefixCache` — prompt-prefix radix trie (prefix_cache.py)
  * paged-cache building blocks (paged_cache.py) for tests and tools
"""
from .engine import FinishedRequest, ServeConfig, ServeEngine, ServeReport
from .live_db import LiveParamDB, ReadRecord, StaticParams
from .paged_cache import (PageAllocator, init_paged_cache, make_activate_fn,
                          make_copy_page_fn, make_evict_fn, make_join_fn,
                          page_classes)
from .prefix_cache import PrefixCache
from .workload import Request, open_loop_requests, shared_prefix_requests

__all__ = [
    "FinishedRequest", "LiveParamDB", "PageAllocator", "PrefixCache",
    "ReadRecord", "Request", "ServeConfig", "ServeEngine", "ServeReport",
    "StaticParams", "init_paged_cache", "make_activate_fn",
    "make_copy_page_fn", "make_evict_fn", "make_join_fn",
    "open_loop_requests", "page_classes", "shared_prefix_requests",
]
