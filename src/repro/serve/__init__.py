"""Request-level serving: continuous batching over a live ParameterDB.

Public surface:

  * :class:`ServeEngine` / :class:`ServeConfig` — the engine (engine.py)
  * :func:`open_loop_requests` / :class:`Request` — workload (workload.py)
  * :class:`LiveParamDB` / :class:`StaticParams` — parameter handles
    (live_db.py)
  * paged-cache building blocks (paged_cache.py) for tests and tools
"""
from .engine import FinishedRequest, ServeConfig, ServeEngine, ServeReport
from .live_db import LiveParamDB, ReadRecord, StaticParams
from .paged_cache import (PageAllocator, init_paged_cache, make_evict_fn,
                          make_join_fn, page_classes)
from .workload import Request, open_loop_requests

__all__ = [
    "FinishedRequest", "LiveParamDB", "PageAllocator", "ReadRecord",
    "Request", "ServeConfig", "ServeEngine", "ServeReport", "StaticParams",
    "init_paged_cache", "make_evict_fn", "make_join_fn",
    "open_loop_requests", "page_classes",
]
