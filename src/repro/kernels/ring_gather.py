"""Pallas gather-of-versions for the ParameterDB delta ring buffer.

The JAX backend (pdb/jax_backend.py) keeps the last ``delta + 1``
parameter versions stacked along a leading axis.  Reading the version at
admissible delay ``d`` is one row-gather ``hist[(ptr - d) % size]`` —
but done leaf-by-leaf (the historical path) it lowers to one
dynamic-slice DMA per pytree leaf, dozens per step for the zoo models.

Here the row index arrives through scalar prefetch
(``PrefetchScalarGridSpec``), so it is known before the kernel body runs
and the BlockSpec index map itself selects the ring row: the whole
gather is pure DMA over lane-aligned tiles of one *packed* (size, N)
buffer — one kernel launch per parameter group, regardless of how many
leaves the group holds.

The packed layout (leaves grouped by (delay, dtype), flattened and
concatenated, N padded to the 128-lane tile) is built once at engine
init by pdb/jax_backend.py; values round-trip bit-exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, hist_ref, out_ref):
    del idx_ref  # consumed by the BlockSpec index maps
    out_ref[...] = hist_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ring_gather(hist: jnp.ndarray, idx: jnp.ndarray, block: int = 1024,
                interpret: bool = False) -> jnp.ndarray:
    """hist: (size, N); idx: scalar int32 in [0, size) -> hist[idx] (N,).

    N need not divide ``block``; Pallas clips the trailing tile.  For
    peak DMA efficiency pack N to a multiple of 128 lanes (the jax
    backend's packer does).
    """
    size, N = hist.shape
    block = min(block, N)
    idx = jnp.asarray(idx, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pl.cdiv(N, block),),
        in_specs=[pl.BlockSpec((1, block), lambda i, idx_ref: (idx_ref[0], i))],
        out_specs=pl.BlockSpec((1, block), lambda i, idx_ref: (0, i)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, N), hist.dtype),
        interpret=interpret,
    )(idx, hist)
    return out[0]
