"""Jit'd dispatch layer over the Pallas kernels and their jnp references.

The models call only these entry points.  Implementation choice:

  * ``REPRO_KERNEL_IMPL=ref``      (default) — XLA path; used on CPU, in the
    dry-run lowering, and anywhere Pallas-to-backend lowering is unavailable.
  * ``REPRO_KERNEL_IMPL=pallas``   — the Pallas TPU kernels (real hardware).
  * ``REPRO_KERNEL_IMPL=interpret`` — Pallas kernels in interpret mode
    (Python emulation on CPU; what the kernel tests use).

Both paths compute identical math — tests/test_kernels.py sweeps shapes and
dtypes asserting allclose between them.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from . import ref

_VALID = ("ref", "pallas", "interpret")


def kernel_impl() -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", "ref")
    if impl not in _VALID:
        raise ValueError(f"REPRO_KERNEL_IMPL={impl!r}; want one of {_VALID}")
    return impl


# sequences at or above this length take the blockwise XLA path (bounded
# score-matrix memory); below it the plain path fuses better
CHUNK_THRESHOLD = int(os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD", 4096))


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: int = 0,
              impl: str | None = None) -> jnp.ndarray:
    impl = impl or kernel_impl()
    if impl == "ref":
        S = q.shape[1]
        if S >= CHUNK_THRESHOLD and S % min(1024, S) == 0 \
                and q.shape[1] == k.shape[1]:
            return ref.attention_chunked(q, k, v, causal=causal,
                                         window=window)
        return ref.attention(q, k, v, causal=causal, window=window)
    from .flash_attention import flash_attention
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=(impl == "interpret"))


def attention_decode(q, k, v, valid, impl: str | None = None):
    impl = impl or kernel_impl()
    if impl == "ref":
        return ref.attention_decode(q, k, v, valid)
    from .decode_attention import decode_attention
    return decode_attention(q, k, v, valid, interpret=(impl == "interpret"))


def ring_gather(hist, idx, impl: str | None = None):
    """Gather one stacked version: hist[(size, N)], idx scalar -> (N,)."""
    impl = impl or kernel_impl()
    if impl == "ref":
        return ref.ring_gather(hist, idx)
    from .ring_gather import ring_gather as _rg
    return _rg(hist, idx, interpret=(impl == "interpret"))


def page_gather(pool, page_table, impl: str | None = None):
    """Paged-KV logical view: pool (P, page, ...) + page_table (B, n_pp)
    -> (B, n_pp * page, ...) — the serving engine's cache materializer."""
    impl = impl or kernel_impl()
    if impl == "ref":
        return ref.page_gather(pool, page_table)
    from .page_gather import page_gather as _pg
    return _pg(pool, page_table, interpret=(impl == "interpret"))


def prefill_page_attention(q, k_ctx, v_ctx, k_new, v_new, ctx_pos, q_pos,
                           window: int = 0, impl: str | None = None):
    """Chunked-prefill attention: chunk queries (B, C, H, hd) against the
    gathered paged context (B, L, KV, hd) plus in-chunk keys, masked by
    absolute positions (ctx_pos/q_pos; negative = dead slot)."""
    impl = impl or kernel_impl()
    if impl == "ref":
        return ref.prefill_page_attention(q, k_ctx, v_ctx, k_new, v_new,
                                          ctx_pos, q_pos, window=window)
    from .page_gather import prefill_page_attention as _ppa
    return _ppa(q, k_ctx, v_ctx, k_new, v_new, ctx_pos, q_pos,
                window=window, interpret=(impl == "interpret"))


def moe_grouped_ffn(dispatch, combine, xg, wg, wu, wd, ep=None,
                    impl: str | None = None):
    """Grouped-expert FFN over dispatched token groups (models/moe.py).

    The ``ep`` sharding hook only applies on the XLA path — the Pallas
    kernel never materializes the dispatched (E, G, C, d) intermediate it
    would constrain.
    """
    impl = impl or kernel_impl()
    if impl == "ref":
        return ref.moe_grouped_ffn(dispatch, combine, xg, wg, wu, wd, ep=ep)
    from .moe_matmul import moe_grouped_ffn as _moe
    return _moe(dispatch, combine, xg, wg, wu, wd,
                interpret=(impl == "interpret"))


def rwkv6(r, k, v, w, u, impl: str | None = None):
    impl = impl or kernel_impl()
    if impl == "ref":
        return ref.rwkv6(r, k, v, w, u)
    from .rwkv6_scan import rwkv6_scan
    return rwkv6_scan(r, k, v, w, u, interpret=(impl == "interpret"))


def rwkv6_stateful(r, k, v, w, u, S0, impl: str | None = None):
    # Stateful (decode) path: T is tiny; the scan reference is optimal.
    return ref.rwkv6_stateful(r, k, v, w, u, S0)


def rglru(x, a, impl: str | None = None):
    impl = impl or kernel_impl()
    if impl == "ref":
        h, _ = ref.rglru(x, a)
        return h
    from .rglru_scan import rglru_scan
    return rglru_scan(x, a, interpret=(impl == "interpret"))


def rglru_stateful(x, a, h0, impl: str | None = None):
    return ref.rglru(x, a, h0)
