"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

Per (batch, head) the recurrence carries a (hd, hd) f32 state matrix:

    y_t   = r_t @ S_t + (r_t . (u * k_t)) v_t
    S_t+1 = diag(w_t) S_t + k_t v_t^T

Tiling: grid = (B, H, T // block_t); the time axis is minor-most so the
state matrix persists in VMEM scratch across time blocks of one (b, h).
Inside a block we jax.lax.fori_loop over the block_t steps; each step is a
(hd,)x(hd,hd) matvec + rank-1 update — hd=64 keeps the state at 16 KiB f32,
far below VMEM limits, and the (block_t, hd) operand tiles stream through.

This is the TPU-native adaptation of the CUDA wkv kernels: instead of one
thread per channel with warp-level reductions, whole (hd, hd) panels live
in VMEM and the MXU/VPU execute the matvec/outer-product per step.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_scr, *,
                block_t: int, seq_len: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0].astype(jnp.float32)                    # (hd,)

    def step(t, S):
        # refs hold one (1,1,block_t,hd) tile; index the time row
        r_t = r_ref[0, 0, t].astype(jnp.float32)        # (hd,)
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        y = r_t @ S + jnp.sum(r_t * u * k_t) * v_t      # (hd,)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        return S * w_t[:, None] + k_t[:, None] * v_t[None, :]

    n_valid = jnp.minimum(block_t, seq_len - it * block_t)
    state_scr[...] = jax.lax.fori_loop(0, n_valid, step, state_scr[...])


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray,
               block_t: int = 64, interpret: bool = False) -> jnp.ndarray:
    """r, k, v, w: (B, T, H, hd); u: (H, hd) -> (B, T, H, hd)."""
    B, T, H, hd = r.shape
    block_t = min(block_t, T)
    T_pad = math.ceil(T / block_t) * block_t
    if T_pad != T:
        pad = ((0, 0), (0, T_pad - T), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)

    # (B, H, T, hd) layout: time blocked, head in grid
    rt, kt, vt, wt = (x.transpose(0, 2, 1, 3) for x in (r, k, v, w))

    grid = (B, H, T_pad // block_t)
    spec = pl.BlockSpec((1, 1, block_t, hd), lambda b, h, it: (b, h, it, 0))
    u_spec = pl.BlockSpec((1, hd), lambda b, h, it: (h, 0))

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, block_t=block_t, seq_len=T),
        grid=grid,
        in_specs=[spec, spec, spec, spec, u_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T_pad, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)

    return out.transpose(0, 2, 1, 3)[:, :T]
