"""Pure-jnp oracles for every kernel.  These are the correctness ground
truth (tests assert the Pallas kernels match them) AND the XLA execution
path used on CPU / in the dry-run lowering."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each kv head."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Full-sequence attention with GQA.

    q: (B, S, H, hd);  k, v: (B, S, KV, hd)  ->  (B, S, H, hd).
    window > 0 restricts key positions to (qpos - window, qpos].
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def attention_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, window: int = 0,
                      block_q: int = 1024) -> jnp.ndarray:
    """Blockwise attention for long sequences on the XLA path: scan over
    query chunks so the score matrix never exceeds (block_q, S) per
    batch-head — the flash-attention memory bound without Pallas.  This is
    what the dry-run lowers for seq >= _CHUNK_THRESHOLD; on TPU hardware the
    Pallas kernel (kernels/flash_attention.py) replaces it."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    bq = min(block_q, Sq)
    assert Sq % bq == 0, (Sq, bq)
    nq = Sq // bq
    scale = hd ** -0.5

    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, hd), 1, 0)   # (nq,B,bq,H,hd)
    kpos = jnp.arange(Sk)[None, :]

    def chunk(i, qc):
        qstart = i * bq
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, k,
                            preferred_element_type=jnp.float32) * scale
        qpos = qstart + jnp.arange(bq)[:, None]
        mask = jnp.ones((bq, Sk), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)

    out = jax.lax.map(lambda args: chunk(*args),
                      (jnp.arange(nq), qb))                # (nq,B,bq,H,hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd).astype(q.dtype)


def attention_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid: jnp.ndarray) -> jnp.ndarray:
    """Single-token decode.  q: (B, 1, H, hd); k, v: (B, L, KV, hd);
    valid: (L,) or per-sequence (B, L) bool mask of live cache slots
    (continuous batching puts every sequence at its own position).  At
    least one slot per sequence must be valid."""
    B, _, H, hd = q.shape
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    vmask = valid[None, :] if valid.ndim == 1 else valid        # (B, L)
    scores = jnp.where(vmask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def prefill_page_attention(q: jnp.ndarray, k_ctx: jnp.ndarray,
                           v_ctx: jnp.ndarray, k_new: jnp.ndarray,
                           v_new: jnp.ndarray, ctx_pos: jnp.ndarray,
                           q_pos: jnp.ndarray,
                           window: int = 0) -> jnp.ndarray:
    """Chunked-prefill attention against a gathered ring/paged context.

    q, k_new, v_new: (B, C, H|KV, hd) — the current prompt chunk (RoPE'd);
    k_ctx, v_ctx: (B, L, KV, hd) — the logical ring view of prior chunks'
    pages (page_gather output); ctx_pos: (B, L) int32 absolute position
    held by each ring slot, negative = dead slot; q_pos: (B, C) int32
    absolute positions of the chunk tokens.  Keys are masked to
    ``0 <= kpos <= qpos`` (and ``kpos > qpos - window`` when window > 0),
    so a chunk starting mid-sequence attends to exactly the prefix it
    would see in a full-sequence prefill.  Returns (B, C, H, hd).
    """
    B, C, H, hd = q.shape
    k = jnp.concatenate([k_ctx, k_new.astype(k_ctx.dtype)], axis=1)
    v = jnp.concatenate([v_ctx, v_new.astype(v_ctx.dtype)], axis=1)
    kpos = jnp.concatenate([ctx_pos, q_pos], axis=1)        # (B, L + C)
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        mask &= kpos[:, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(mask[:, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def ring_gather(hist: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """hist: (size, ...) stacked versions; idx: scalar -> hist[idx]."""
    return jax.lax.dynamic_index_in_dim(hist, jnp.asarray(idx, jnp.int32),
                                        axis=0, keepdims=False)


def page_gather(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """pool: (P, page, ...); page_table: (B, n_pp) int32 ->
    (B, n_pp * page, ...) — the paged KV cache's logical view."""
    B, n_pp = page_table.shape
    out = pool[page_table]                       # (B, n_pp, page, ...)
    return out.reshape((B, n_pp * pool.shape[1]) + pool.shape[2:])


def moe_grouped_ffn(dispatch: jnp.ndarray, combine: jnp.ndarray,
                    xg: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
                    wd: jnp.ndarray, ep=None) -> jnp.ndarray:
    """Dense one-hot MoE dispatch (GShard style), the XLA path.

    dispatch: (G, g, E, C) bool; combine: (G, g, E, C) f32; xg: (G, g, d);
    wg/wu: (E, d, f); wd: (E, f, d) -> (G, g, d).  ``ep`` optionally
    constrains the dispatched intermediates' sharding (models/moe.py).
    """
    if ep is None:
        ep = lambda t: t
    xin = ep(jnp.einsum("GgEC,Ggd->EGCd", dispatch.astype(xg.dtype), xg))
    h = jax.nn.silu(jnp.einsum("EGCd,Edf->EGCf", xin, wg))
    u = jnp.einsum("EGCd,Edf->EGCf", xin, wu)
    out_e = ep(jnp.einsum("EGCf,Efd->EGCd", h * u, wd))
    return jnp.einsum("GgEC,EGCd->Ggd", combine.astype(xg.dtype), out_e)


def rwkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          w: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """RWKV-6 WKV recurrence (Finch, arXiv:2404.05892).

    r, k, v, w: (B, T, H, hd) with w in (0,1) the data-dependent decay;
    u: (H, hd) the current-token bonus.  Returns (B, T, H, hd).

        y_t[j] = sum_i r_t[i] * (S_t[i,j] + u[i] k_t[i] v_t[j])
        S_{t+1}[i,j] = w_t[i] S_t[i,j] + k_t[i] v_t[j]
    """
    B, T, H, hd = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                        # (B, H, hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S)
        bonus = jnp.einsum("bhi,bhi->bh", r_t, uf[None] * k_t)
        y = y + bonus[..., None] * v_t
        S = S * w_t[..., :, None] + k_t[..., :, None] * v_t[..., None, :]
        return S, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)


def rwkv6_stateful(r, k, v, w, u, S0):
    """Decode-friendly variant: explicit input/output state (B,H,hd,hd)."""
    B, T, H, hd = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        y = jnp.einsum("bhi,bhij->bhj", r_t, S)
        bonus = jnp.einsum("bhi,bhi->bh", r_t, uf[None] * k_t)
        y = y + bonus[..., None] * v_t
        S = S * w_t[..., :, None] + k_t[..., :, None] * v_t[..., None, :]
        return S, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    S1, ys = jax.lax.scan(step, S0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S1


def rglru(x: jnp.ndarray, a: jnp.ndarray,
          h0: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """RG-LRU linear recurrence (Griffin, arXiv:2402.19427).

    x: (B, T, D) gated input (i_t * x_t); a: (B, T, D) decay in (0,1).
        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t
    Returns (h (B,T,D), final state (B,D)).
    """
    B, T, D = x.shape
    xf, af = x.astype(jnp.float32), a.astype(jnp.float32)
    gate = jnp.sqrt(jnp.clip(1.0 - af * af, 0.0, 1.0))

    def step(h, inp):
        x_t, a_t, g_t = inp
        h = a_t * h + g_t * x_t
        return h, h

    init = (jnp.zeros((B, D), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0),
          jnp.moveaxis(gate, 1, 0))
    hT, hs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hT
