"""Pallas TPU flash attention (causal + sliding window, GQA-aware).

Tiling: grid = (batch, q_heads, q_blocks, kv_blocks); the kv_blocks axis is
minor-most, so on TPU it iterates sequentially per (b, h, iq) and the online
softmax state (m, l, acc) lives in VMEM scratch across kv iterations.
GQA is handled in the BlockSpec index maps (kv head = q head // group), so
K/V are never materialized per-q-head.

Block shapes are multiples of the (8, 128) VPU / 128x128 MXU tiles; the
working set per grid step is q(bq,hd) + k(bk,hd) + v(bk,hd) + acc(bq,hd)
f32 scratch — e.g. bq=bk=256, hd=128: ~512 KiB, comfortably inside the
~16 MiB v5e VMEM even with double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # skip blocks that are entirely masked out (causal/window locality)
    def masked_out() -> jnp.ndarray:
        done = jnp.bool_(False)
        if causal:
            done |= k_start > q_start + block_q - 1
        if window > 0:
            done |= k_start + block_k - 1 <= q_start - window
        return done

    @pl.when(jnp.logical_not(masked_out()))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    scale = hd ** -0.5

    block_q = min(block_q, S)
    block_k = min(block_k, S)
    # pad sequence to block multiples (kernel masks the tail)
    S_pad = math.ceil(S / block_q) * block_q
    S_pad = math.ceil(S_pad / block_k) * block_k
    if S_pad != S:
        pad = ((0, 0), (0, S_pad - S), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    # (B, H, S, hd) layout: heads in grid, seq blocked
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, S_pad // block_q, S_pad // block_k)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)

    return out.transpose(0, 2, 1, 3)[:, :S]
