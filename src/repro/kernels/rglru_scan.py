"""Pallas TPU kernel for the RG-LRU gated linear recurrence (Griffin).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t        (elementwise over D)

Tiling: grid = (B, D // block_d, T // block_t); time minor-most so the
(block_d,) state vector persists in VMEM scratch per (b, d-tile).  Channel
tiles are independent, so the D axis parallelizes across TPU cores; the
inner fori_loop walks block_t steps with pure VPU elementwise work.
block_d is a multiple of 128 lanes; block_t deep enough to amortize grid
overhead (default 128 x 256 tile = 128 KiB f32 in flight).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, a_ref, y_ref, h_scr, *, block_t: int, seq_len: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)           # (block_d,)
        a_t = a_ref[0, t].astype(jnp.float32)
        g_t = jnp.sqrt(jnp.clip(1.0 - a_t * a_t, 0.0, 1.0))
        h = a_t * h + g_t * x_t
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    n_valid = jnp.minimum(block_t, seq_len - it * block_t)
    h_scr[...] = jax.lax.fori_loop(0, n_valid, step, h_scr[...])


@functools.partial(jax.jit, static_argnames=("block_d", "block_t",
                                             "interpret"))
def rglru_scan(x: jnp.ndarray, a: jnp.ndarray, block_d: int = 128,
               block_t: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x, a: (B, T, D) -> h: (B, T, D)."""
    B, T, D = x.shape
    block_t = min(block_t, T)
    block_d = min(block_d, D)
    T_pad = math.ceil(T / block_t) * block_t
    D_pad = math.ceil(D / block_d) * block_d
    if (T_pad, D_pad) != (T, D):
        pad = ((0, 0), (0, T_pad - T), (0, D_pad - D))
        x = jnp.pad(x, pad)
        a = jnp.pad(a, pad)

    grid = (B, D_pad // block_d, T_pad // block_t)
    spec = pl.BlockSpec((1, block_t, block_d), lambda b, id_, it: (b, it, id_))

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t, seq_len=T),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T_pad, D_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(x, a)

    return out[:, :T, :D]
