"""Pallas page-table gather for the paged serving KV cache.

The serving engine (repro.serve) keeps each attention layer's KV cache as
a pool of fixed-size pages shared by all sequence slots; a per-sequence
page table maps logical cache pages to physical pool pages
(vLLM-style paged attention, restricted to gather-before-attend).

Materializing the logical (B, L, KV, hd) view is then a row-gather of
``B * pages_per_seq`` pool rows.  Like kernels/ring_gather.py, the page
ids arrive through scalar prefetch (``PrefetchScalarGridSpec``) so the
BlockSpec index map itself selects the physical page: the gather is pure
DMA over lane-aligned tiles, one grid step per (page, tile) — no
compute, no scatter, regardless of how fragmented the page table is.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


def _gather_kernel(pt_ref, pool_ref, out_ref):
    del pt_ref  # consumed by the BlockSpec index maps
    out_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def page_gather(pool: jnp.ndarray, page_table: jnp.ndarray,
                block: int = 1024, interpret: bool = False) -> jnp.ndarray:
    """pool: (P, page, ...); page_table: (B, n_pp) int32 in [0, P).

    Returns the logical view (B, n_pp * page, ...) in pool dtype, i.e.
    ``pool[page_table]`` with the page axis folded into the cache axis.
    """
    P, page = pool.shape[0], pool.shape[1]
    tail = pool.shape[2:]
    B, n_pp = page_table.shape
    row = page * math.prod(tail)
    rows = pool.reshape(P, row)
    idx = page_table.reshape(-1).astype(jnp.int32)        # (B * n_pp,)
    block = min(block, row)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * n_pp, pl.cdiv(row, block)),
        in_specs=[pl.BlockSpec((1, block),
                               lambda i, j, pt_ref: (pt_ref[i], j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j, pt_ref: (i, j)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * n_pp, row), pool.dtype),
        interpret=interpret,
    )(idx, rows)
    return out.reshape((B, n_pp * page) + tail)


def _prefill_kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
                    m_scr, l_scr, acc_scr, *, scale: float, window: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (R, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = kpos_ref[0]                                  # (bk,)
    qp = qpos_ref[0]                                    # (R,)
    live = (kpos[None, :] >= 0) & (kpos[None, :] <= qp[:, None])
    if window > 0:
        live &= kpos[None, :] > qp[:, None] - window
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_scr[...]                                 # (R,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    # re-mask probabilities: an all-dead block would otherwise
    # contribute exp(NEG_INF - NEG_INF) = 1 per slot
    p = jnp.exp(s - m_cur[:, None]) * live.astype(jnp.float32)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "block_k", "interpret"))
def prefill_page_attention(q: jnp.ndarray, k_ctx: jnp.ndarray,
                           v_ctx: jnp.ndarray, k_new: jnp.ndarray,
                           v_new: jnp.ndarray, ctx_pos: jnp.ndarray,
                           q_pos: jnp.ndarray, window: int = 0,
                           block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """Chunked-prefill flash attention over a gathered paged context.

    q, k_new, v_new: (B, C, H|KV, hd) current chunk; k_ctx, v_ctx:
    (B, L, KV, hd) logical ring view of prior chunks (page_gather
    output); ctx_pos: (B, L) int32 absolute position per ring slot
    (negative = dead); q_pos: (B, C) int32 chunk-token positions.

    One grid step covers one (batch, kv_head) pair with the whole
    chunk's query-head group flattened into rows, the concatenated
    ctx+chunk key axis blocked minor-most with online-softmax scratch —
    the chunk-sized generalization of decode_attention, masked by
    absolute position (0 <= kpos <= qpos, plus sliding window) instead
    of a precomputed valid vector.  Matches ref.prefill_page_attention.
    """
    B, C, H, hd = q.shape
    L, KV = k_ctx.shape[1], k_ctx.shape[2]
    group = H // KV
    scale = hd ** -0.5

    k = jnp.concatenate([k_ctx, k_new.astype(k_ctx.dtype)], axis=1)
    v = jnp.concatenate([v_ctx, v_new.astype(v_ctx.dtype)], axis=1)
    kpos = jnp.concatenate([ctx_pos, q_pos], axis=1).astype(jnp.int32)
    T = L + C
    block_k = min(block_k, T)
    T_pad = math.ceil(T / block_k) * block_k
    if T_pad != T:
        k = jnp.pad(k, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, T_pad - T)), constant_values=-1)

    # queries: (B, C, H, hd) -> (B, KV, group * C, hd); row r is
    # (head kv*group + r // C, chunk token r % C)
    R = group * C
    R_pad = math.ceil(R / 8) * 8
    qt = q.transpose(0, 2, 1, 3).reshape(B, KV, R, hd)
    qpos_row = jnp.tile(q_pos.astype(jnp.int32), (1, group))  # (B, R)
    if R_pad != R:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, R_pad - R), (0, 0)))
        qpos_row = jnp.pad(qpos_row, ((0, 0), (0, R_pad - R)),
                           constant_values=-1)
    kt = k.transpose(0, 2, 1, 3)                        # (B, KV, T_pad, hd)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, KV, T_pad // block_k)
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, scale=scale, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, R_pad, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ik: (b, ik)),
            pl.BlockSpec((1, R_pad), lambda b, h, ik: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R_pad, hd),
                               lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, R_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((R_pad,), jnp.float32),          # running max m
            pltpu.VMEM((R_pad,), jnp.float32),          # running sum l
            pltpu.VMEM((R_pad, hd), jnp.float32),       # accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt, kpos, qpos_row)

    out = out[:, :, :R].reshape(B, KV, group, C, hd)
    return out.reshape(B, H, C, hd).transpose(0, 2, 1, 3)
