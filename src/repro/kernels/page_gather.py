"""Pallas page-table gather for the paged serving KV cache.

The serving engine (repro.serve) keeps each attention layer's KV cache as
a pool of fixed-size pages shared by all sequence slots; a per-sequence
page table maps logical cache pages to physical pool pages
(vLLM-style paged attention, restricted to gather-before-attend).

Materializing the logical (B, L, KV, hd) view is then a row-gather of
``B * pages_per_seq`` pool rows.  Like kernels/ring_gather.py, the page
ids arrive through scalar prefetch (``PrefetchScalarGridSpec``) so the
BlockSpec index map itself selects the physical page: the gather is pure
DMA over lane-aligned tiles, one grid step per (page, tile) — no
compute, no scatter, regardless of how fragmented the page table is.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(pt_ref, pool_ref, out_ref):
    del pt_ref  # consumed by the BlockSpec index maps
    out_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def page_gather(pool: jnp.ndarray, page_table: jnp.ndarray,
                block: int = 1024, interpret: bool = False) -> jnp.ndarray:
    """pool: (P, page, ...); page_table: (B, n_pp) int32 in [0, P).

    Returns the logical view (B, n_pp * page, ...) in pool dtype, i.e.
    ``pool[page_table]`` with the page axis folded into the cache axis.
    """
    P, page = pool.shape[0], pool.shape[1]
    tail = pool.shape[2:]
    B, n_pp = page_table.shape
    row = page * math.prod(tail)
    rows = pool.reshape(P, row)
    idx = page_table.reshape(-1).astype(jnp.int32)        # (B * n_pp,)
    block = min(block, row)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * n_pp, pl.cdiv(row, block)),
        in_specs=[pl.BlockSpec((1, block),
                               lambda i, j, pt_ref: (pt_ref[i], j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j, pt_ref: (i, j)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * n_pp, row), pool.dtype),
        interpret=interpret,
    )(idx, rows)
    return out.reshape((B, n_pp * page) + tail)
