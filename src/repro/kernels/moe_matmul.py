"""Pallas grouped MoE FFN: per-expert block contraction, no EGCd tensor.

The XLA reference (kernels/ref.py:moe_grouped_ffn) materializes the
dispatched activations ``xin = einsum("GgEC,Ggd->EGCd", dispatch, x)`` —
an (E, G, C, d) tensor written to and re-read from HBM three times (gate,
up, down projections) plus the combine einsum.  This kernel fuses the
whole expert computation per (token-group, expert) grid step:

    xin_e = dispatch_e^T @ x_G          (C, d)   -- one-hot gather-as-matmul
    y_e   = (silu(xin_e @ wg_e) * (xin_e @ wu_e)) @ wd_e
    out_G += combine_e @ y_e            (g, d)   -- accumulated in VMEM

so dispatched activations and per-expert outputs never leave VMEM.  The
expert axis is minor-most in the grid; the (g, d) output accumulator
lives in f32 scratch across experts and is written once.

Weights stream per expert via the BlockSpec index maps — each expert's
(d, f)/(f, d) matrices must fit VMEM alongside the (C, d)/(C, f)
activations; block over f (future work) lifts that for the full-scale
configs.  Sharding (expert-parallel layouts) stays on the XLA path; this
kernel is the single-device fast path under shard_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(dispT_ref, comb_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref,
                acc_scr):
    e = pl.program_id(1)
    nE = pl.num_programs(1)

    @pl.when(e == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    dispT = dispT_ref[0, 0].astype(jnp.float32)        # (C, g)
    x = x_ref[0].astype(jnp.float32)                   # (g, d)
    wg = wg_ref[0].astype(jnp.float32)                 # (d, f)
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)                 # (f, d)

    mm = functools.partial(jax.lax.dot_general,
                           dimension_numbers=(((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32)
    xin = mm(dispT, x)                                 # (C, d)
    h = jax.nn.silu(mm(xin, wg))                       # (C, f)
    u = mm(xin, wu)
    y = mm(h * u, wd)                                  # (C, d)
    comb = comb_ref[0, 0].astype(jnp.float32)          # (g, C)
    acc_scr[...] = acc_scr[...] + mm(comb, y)          # (g, d)

    @pl.when(e == nE - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_grouped_ffn(dispatch: jnp.ndarray, combine: jnp.ndarray,
                    xg: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
                    wd: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    """dispatch: (G, g, E, C) bool; combine: (G, g, E, C) f32;
    xg: (G, g, d); wg/wu: (E, d, f); wd: (E, f, d) -> (G, g, d) in xg.dtype.
    """
    G, g, E, C = dispatch.shape
    d = xg.shape[-1]

    dispT = dispatch.astype(xg.dtype).transpose(0, 2, 3, 1)   # (G, E, C, g)
    comb = combine.transpose(0, 2, 1, 3)                      # (G, E, g, C)

    grid = (G, E)
    out = pl.pallas_call(
        _moe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, C, g), lambda gi, e: (gi, e, 0, 0)),
            pl.BlockSpec((1, 1, g, C), lambda gi, e: (gi, e, 0, 0)),
            pl.BlockSpec((1, g, d), lambda gi, e: (gi, 0, 0)),
            pl.BlockSpec((1,) + wg.shape[1:], lambda gi, e: (e, 0, 0)),
            pl.BlockSpec((1,) + wu.shape[1:], lambda gi, e: (e, 0, 0)),
            pl.BlockSpec((1,) + wd.shape[1:], lambda gi, e: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda gi, e: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, g, d), xg.dtype),
        scratch_shapes=[pltpu.VMEM((g, d), jnp.float32)],
        interpret=interpret,
    )(dispT, comb, xg, wg, wu, wd)
    return out
