"""Pallas TPU kernels for the compute hot-spots, with jnp reference oracles.

  flash_attention — tiled online-softmax attention (causal + window, GQA)
  rwkv6_scan      — RWKV-6 WKV recurrence ((hd,hd) state in VMEM scratch)
  rglru_scan      — Griffin RG-LRU gated linear recurrence
  ops             — jit'd dispatch (ref | pallas | interpret)
  ref             — pure-jnp oracles (ground truth + XLA execution path)
"""
from . import ops, ref  # noqa: F401
