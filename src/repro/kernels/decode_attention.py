"""Pallas TPU fused single-token decode attention (GQA, ring-cache aware).

One grid step handles one (batch, kv_head) pair: the whole query-head
*group* that shares a KV head attends at once, so K/V rows are read from
HBM exactly once regardless of the GQA ratio — the memory-bound quantity
for decode.  The kv-cache axis is blocked minor-most with online-softmax
state (m, l, acc) in VMEM scratch, exactly like the prefill flash kernel,
so cache length is bounded only by HBM.

The cache is addressed positionally: callers pass the ``valid`` mask
produced by the ``slot = pos % L`` ring convention
(models/attention.py), so dead slots (not yet written, or outside the
sliding window) are masked here rather than by cache compaction — the
kernel is paged/ring-cache friendly by construction and never needs the
absolute positions.

Layout: q (B, 1, H, hd), k/v (B, L, KV, hd) are transposed to put the kv
head in the grid and the cache axis in blocks; scores per step are
(group, block_k) with group = H // KV.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (group, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    live = valid_ref[0] > 0                             # (bk,)
    s = jnp.where(live[None, :], s, NEG_INF)

    m_prev = m_scr[...]                                 # (group,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    # mask the probabilities too: an all-dead block would otherwise
    # contribute exp(NEG_INF - NEG_INF) = 1 per slot
    p = jnp.exp(s - m_cur[:, None]) * live[None, :].astype(jnp.float32)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid: jnp.ndarray, block_k: int = 128,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, 1, H, hd); k, v: (B, L, KV, hd); valid: (L,) or (B, L) bool
    (per-sequence masks — continuous batching decodes every sequence at
    its own ring position).

    Returns (B, 1, H, hd) in q.dtype.  Matches ref.attention_decode.
    """
    B, _, H, hd = q.shape
    L, KV = k.shape[1], k.shape[2]
    group = H // KV
    scale = hd ** -0.5

    block_k = min(block_k, L)
    L_pad = math.ceil(L / block_k) * block_k
    validp = jnp.asarray(valid, jnp.int32)
    if validp.ndim == 1:
        validp = validp[None]
    if L_pad != L:
        pad = ((0, 0), (0, L_pad - L), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        validp = jnp.pad(validp, ((0, 0), (0, L_pad - L)))
    validp = jnp.broadcast_to(validp, (B, L_pad))

    # q: (B, KV, group, hd); k/v: (B, KV, L_pad, hd)
    qt = q.reshape(B, KV, group, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, KV, L_pad // block_k)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),          # running max m
            pltpu.VMEM((group,), jnp.float32),          # running sum l
            pltpu.VMEM((group, hd), jnp.float32),       # accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt, validp)

    return out.reshape(B, 1, H, hd)
