"""Failure handling for the training loop.

At thousand-node scale the interesting failures are: a worker process dies
(job restart from checkpoint), a step produces non-finite loss (data/HW
fault -> skip or re-run), and persistent stragglers (mitigated by the
data-centric scheduler's delta tolerance at the host level — see
repro.core.simulator backup_tasks for the speculative-execution variant).

``run_with_recovery`` wraps a step function with: deterministic failure
injection (for tests/drills), non-finite-loss detection, bounded retries,
and checkpoint-resume integration.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 2
    skip_nonfinite: bool = True     # skip a poisoned batch instead of dying


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically kill specific steps (restart drills)."""
    fail_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


def run_with_recovery(step_fn: Callable[[Any, Any], tuple[Any, dict]],
                      state: Any, batch: Any, step: int,
                      policy: RetryPolicy,
                      injector: FailureInjector | None = None,
                      is_finite: Callable[[dict], bool] | None = None,
                      telemetry: Any | None = None
                      ) -> tuple[Any, dict, str]:
    """Execute one training step with recovery.  Returns
    (state, metrics, outcome) where outcome is 'ok' | 'retried' | 'skipped'.
    On non-finite loss the state update is discarded (the prior state is
    returned) — the safe default for poisoned batches.

    ``telemetry`` is an optional :class:`repro.pdb.telemetry.Telemetry`;
    retried and skipped steps are reported into it so one object summarizes
    a run's synchronization *and* fault behavior."""
    attempts = 0
    while True:
        try:
            if injector is not None:
                injector.check(step)
            new_state, metrics = step_fn(state, batch)
            if is_finite is not None and not is_finite(metrics):
                if policy.skip_nonfinite:
                    log.warning("non-finite metrics at step %d; skipping", step)
                    if telemetry is not None:
                        telemetry.on_skip(step)
                    return state, metrics, "skipped"
                raise FloatingPointError(f"non-finite loss at step {step}")
            return new_state, metrics, ("ok" if attempts == 0 else "retried")
        except InjectedFailure:
            raise                      # process-level: handled by restart
        except FloatingPointError:
            raise
        except Exception:              # transient compute failure: retry
            attempts += 1
            if attempts > policy.max_retries:
                raise
            if telemetry is not None:
                telemetry.on_retry(step)
            log.warning("step %d failed (attempt %d); retrying", step, attempts)
