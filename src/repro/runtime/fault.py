"""Failure handling for the training loop and the distributed ParameterDB.

At thousand-node scale the interesting failures are: a worker process dies
(job restart from checkpoint), a *shard* of the parameter server dies
(connection resets on every client touching its chunks), a step produces
non-finite loss (data/HW fault -> skip or re-run), and persistent
stragglers (mitigated by the data-centric scheduler's delta tolerance at
the host level — see repro.core.simulator backup_tasks for the
speculative-execution variant).

``run_with_recovery`` wraps a step function with: deterministic failure
injection (for tests/drills), non-finite-loss detection, bounded retries,
and checkpoint-resume integration.  ``Backoff`` + ``retry_with_backoff``
are the client-side half of shard-death survival: the distributed client
(:mod:`repro.pdb.server.client`) routes every RPC through them, so a
killed-and-restarted shard shows up as ``retried_steps`` in the same
staleness telemetry that describes the run's synchronization behavior.
``ShardDeathPlan`` is the injection half: it kills a chosen shard process
at a chosen step (restart drills for the parameter server).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 2
    skip_nonfinite: bool = True     # skip a poisoned batch instead of dying


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically kill specific steps (restart drills)."""
    fail_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass(frozen=True)
class Backoff:
    """Exponential backoff schedule for reconnect/retry loops."""
    max_retries: int = 8
    base_delay: float = 0.05       # seconds before the first retry
    multiplier: float = 2.0
    max_delay: float = 2.0

    def delay(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based), capped at max_delay."""
        return min(self.base_delay * self.multiplier ** (attempt - 1),
                   self.max_delay)


def retry_with_backoff(fn: Callable[[], Any], backoff: Backoff,
                       retry_on: tuple[type[BaseException], ...]
                       = (ConnectionError, OSError),
                       telemetry: Any | None = None,
                       describe: str = "",
                       on_retry: Callable[[int], None] | None = None) -> Any:
    """Run ``fn`` retrying on transient (connection-shaped) failures with
    exponential backoff.  Each retry is reported into ``telemetry`` (a
    :class:`repro.pdb.telemetry.Telemetry`) so shard reconnects surface in
    the run's staleness summary as ``retried_steps``.  Re-raises the last
    error once the budget is exhausted.

    ``on_retry(attempt)`` runs before each backoff sleep — the hook where a
    caller resets per-attempt state.  The batched RPC client uses it to
    drop the failed shard's connection (discarding any acknowledgements
    still pipelined on the dead socket) so the replayed *batch* starts on a
    frame-aligned stream; replayed sub-ops are deduplicated shard-side, so
    a batch retry is at-least-once delivery with exactly-once recording
    per sub-op."""
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            attempt += 1
            if attempt > backoff.max_retries:
                raise
            if telemetry is not None:
                telemetry.on_retry(attempt)
            if on_retry is not None:
                on_retry(attempt)
            d = backoff.delay(attempt)
            log.warning("%s failed (%s); retry %d/%d in %.2fs",
                        describe or "op", e, attempt, backoff.max_retries, d)
            time.sleep(d)


@dataclasses.dataclass
class ShardDeathPlan:
    """Deterministically kill one parameter-server shard at a given step
    (the distributed analogue of :class:`FailureInjector`).  ``cluster`` is
    a :class:`repro.pdb.server.cluster.ShardCluster`; with ``restart`` the
    shard is immediately relaunched from its snapshot, so clients survive
    via retry_with_backoff."""
    kill_at_step: int
    shard: int = 0
    restart: bool = True
    fired: bool = False

    def maybe_kill(self, step: int, cluster: Any) -> bool:
        if self.fired or step != self.kill_at_step:
            return False
        self.fired = True
        log.warning("injecting shard %d death at step %d", self.shard, step)
        cluster.kill_shard(self.shard)
        if self.restart:
            cluster.restart_shard(self.shard)
        return True


def run_with_recovery(step_fn: Callable[[Any, Any], tuple[Any, dict]],
                      state: Any, batch: Any, step: int,
                      policy: RetryPolicy,
                      injector: FailureInjector | None = None,
                      is_finite: Callable[[dict], bool] | None = None,
                      telemetry: Any | None = None
                      ) -> tuple[Any, dict, str]:
    """Execute one training step with recovery.  Returns
    (state, metrics, outcome) where outcome is 'ok' | 'retried' | 'skipped'.
    On non-finite loss the state update is discarded (the prior state is
    returned) — the safe default for poisoned batches.

    ``telemetry`` is an optional :class:`repro.pdb.telemetry.Telemetry`;
    retried and skipped steps are reported into it so one object summarizes
    a run's synchronization *and* fault behavior."""
    attempts = 0
    while True:
        try:
            if injector is not None:
                injector.check(step)
            new_state, metrics = step_fn(state, batch)
            if is_finite is not None and not is_finite(metrics):
                if policy.skip_nonfinite:
                    log.warning("non-finite metrics at step %d; skipping", step)
                    if telemetry is not None:
                        telemetry.on_skip(step)
                    return state, metrics, "skipped"
                raise FloatingPointError(f"non-finite loss at step {step}")
            return new_state, metrics, ("ok" if attempts == 0 else "retried")
        except InjectedFailure:
            raise                      # process-level: handled by restart
        except FloatingPointError:
            raise
        except Exception:              # transient compute failure: retry
            attempts += 1
            if attempts > policy.max_retries:
                raise
            if telemetry is not None:
                telemetry.on_retry(step)
            log.warning("step %d failed (attempt %d); retrying", step, attempts)
