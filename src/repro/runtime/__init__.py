"""Distributed-runtime substrate: fault handling, elastic scaling hooks."""
from .fault import FailureInjector, RetryPolicy, run_with_recovery  # noqa: F401
