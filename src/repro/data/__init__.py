"""Deterministic synthetic data pipelines."""
from .synthetic import (LMBatchSpec, lm_batch_stream, make_lm_batch,  # noqa: F401
                        regression_dataset, sparse_regression_dataset)
