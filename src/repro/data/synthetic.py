"""Synthetic data generation: token streams for the LM zoo, dense/sparse
regression sets for the paper's Sec-6 experiments.

Determinism contract: batch t of a stream depends only on (seed, t) — any
worker, restart, or re-shard regenerates identical data (this is what makes
checkpoint-resume and elastic re-sharding exactly reproducible).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMBatchSpec:
    batch: int
    seq_len: int
    vocab_size: int
    media_tokens: int = 0           # vision frontend stub
    media_dim: int = 0
    seed: int = 0


def make_lm_batch(spec: LMBatchSpec, step: int) -> dict:
    """Markov-ish synthetic tokens: enough structure for loss to drop."""
    key = jax.random.fold_in(jax.random.PRNGKey(spec.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.randint(k1, (spec.batch, spec.seq_len), 0,
                              spec.vocab_size, dtype=jnp.int32)
    # inject learnable copy structure (vocab-size independent): even
    # positions repeat the previous token, so next-token prediction at odd
    # positions reduces to "repeat the current token" — a few hundred steps
    # suffice for any model size, unlike a vocab-wide permutation task
    shifted = jnp.roll(base, 1, axis=1)
    mask = (jnp.arange(spec.seq_len) % 2 == 0)[None, :]
    tokens = jnp.where(mask, shifted, base)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels,
             "mask": jnp.ones_like(tokens, jnp.float32)
             .at[:, -1].set(0.0)}
    if spec.media_tokens:
        batch["media"] = jax.random.normal(
            k3, (spec.batch, spec.media_tokens, spec.media_dim),
            jnp.float32) * 0.02
    return batch


def lm_batch_stream(spec: LMBatchSpec, start_step: int = 0):
    step = start_step
    while True:
        yield step, make_lm_batch(spec, step)
        step += 1


def regression_dataset(n_examples: int, n_features: int, seed: int = 0,
                       noise: float = 0.01) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n_examples, n_features)) / np.sqrt(n_features)
    w = rng.normal(size=n_features)
    y = X @ w + noise * rng.normal(size=n_examples)
    return X.astype(np.float64), y.astype(np.float64)


def sparse_regression_dataset(n_examples: int, n_features: int,
                              density: float = 0.003, seed: int = 0
                              ) -> tuple[np.ndarray, np.ndarray]:
    """Shape-proxy for the Kogan et al. real dataset (150,360 features,
    16,087 examples, highly sparse).  Returned dense for simplicity at
    reduced sizes; density controls nonzeros."""
    rng = np.random.default_rng(seed)
    X = np.zeros((n_examples, n_features))
    nnz = max(int(n_features * density), 1)
    w = rng.normal(size=n_features)
    for i in range(n_examples):
        idx = rng.choice(n_features, size=nnz, replace=False)
        X[i, idx] = rng.normal(size=nnz)
    y = X @ w + 0.01 * rng.normal(size=n_examples)
    return X, y
