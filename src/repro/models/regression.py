"""The paper's own prototype model: linear regression in JAX.

Feature-partitioned exactly as in Sec 6: the parameter vector theta is split
into p chunks (the partition set Pi); each chunk's update is the paper's
f_i — a deterministic function of the full-theta snapshot.  Used by the
paper-reproduction example and the JAX-engine equivalence tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_theta(n_features: int) -> jnp.ndarray:
    return jnp.zeros((n_features,), jnp.float32)


def loss(theta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    r = X @ theta - y
    return 0.5 * jnp.mean(r * r)


def grad_step(theta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray,
              lr: float) -> jnp.ndarray:
    """One full-batch GD step (all chunk updates from the same snapshot —
    Algorithm 1 semantics)."""
    g = jax.grad(loss)(theta, X, y)
    return theta - lr * g


def chunked_grad_step(theta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray,
                      lr: float, n_chunks: int) -> jnp.ndarray:
    """The same step computed partition-by-partition (worker view): each
    chunk's gradient uses the shared snapshot.  Identical result to
    grad_step — asserted in tests (the paper's sequential-correctness)."""
    resid = X @ theta - y
    n = X.shape[0]
    bounds = jnp.linspace(0, theta.shape[0], n_chunks + 1).astype(int)
    parts = []
    for i in range(n_chunks):
        sl = slice(int(bounds[i]), int(bounds[i + 1]))
        g = X[:, sl].T @ resid / n
        parts.append(theta[sl] - lr * g)
    return jnp.concatenate(parts)
