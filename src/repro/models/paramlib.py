"""Parameter specification trees.

A model describes its parameters once, as a pytree of :class:`P` specs
(shape + logical sharding axes + initializer).  From that single source of
truth we derive:

  * ``init_tree``      — materialized parameters (rng init, real arrays)
  * ``abstract_tree``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run, no
    allocation)
  * ``axes_tree``      — logical-axis tuples, consumed by the sharding
    engine (:mod:`repro.launch.sharding`)

Logical axis names are documented in :mod:`repro.core.sync_jax`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class P:
    """Spec for one parameter tensor."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]            # logical axis name per dim
    init: str = "normal"                    # normal | zeros | ones | scaled
    scale: float | None = None              # stddev; default 1/sqrt(fan_in)
    fan_in_dim: int = -2                    # which dim is fan-in for scaling
    dtype: Any = None                       # override model dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _std(spec: P) -> float:
    if spec.scale is not None:
        return spec.scale
    fan_in = spec.shape[spec.fan_in_dim] if spec.shape else 1
    return 1.0 / math.sqrt(max(fan_in, 1))


def init_tree(specs, key: jax.Array, dtype=jnp.float32):
    """Materialize parameters.  Deterministic per-leaf keys derived by path."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(spec: P, k):
        dt = spec.dtype or dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        return (jax.random.normal(k, spec.shape, jnp.float32)
                * _std(spec)).astype(dt)

    return jax.tree.unflatten(treedef, [make(s, k) for s, k in zip(leaves, keys)])


def abstract_tree(specs, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins — no device allocation (dry-run path)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        specs, is_leaf=_is_spec)


def axes_tree(specs):
    """The logical-axes pytree with the same structure as the params."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    return sum(math.prod(s.shape)
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))


def param_bytes(specs, dtype=jnp.bfloat16) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    return param_count(specs) * itemsize
