"""Self/cross attention with GQA, sliding windows, RoPE and KV caches.

Three entry points per layer kind:
  * ``attention_fwd``   — full-sequence training/prefill forward
  * ``attention_decode`` — single-token decode against a KV cache
  * cross-attention variants for the vision frontend

The softmax path dispatches through :mod:`repro.kernels.ops` so the Pallas
flash kernel (TPU target) and the jnp reference share one call site.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, rmsnorm
from .paramlib import P
from ..kernels import ops as kops


def attn_specs(cfg: ModelConfig, kind: str,
               stack: tuple[int, ...] = ()) -> dict:
    lead = ("layers",) * len(stack)
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    specs = {
        "wq": P(stack + (d, nq * hd), lead + ("embed", "heads")),
        "wk": P(stack + (d, nkv * hd), lead + ("embed", "kv_heads")),
        "wv": P(stack + (d, nkv * hd), lead + ("embed", "kv_heads")),
        "wo": P(stack + (nq * hd, d), lead + ("heads", "embed")),
    }
    if kind == "xattn":  # keys/values come from frontend tokens (same width
        # post-projection); gating scalars stabilize late fusion
        specs["gate"] = P(stack + (1,), lead + (None,), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = P(stack + (hd,), lead + (None,), init="ones")
        specs["k_norm"] = P(stack + (hd,), lead + (None,), init="ones")
    return specs


def _split_heads(x: jnp.ndarray, n: int, hd: int) -> jnp.ndarray:
    return x.reshape(x.shape[:-1] + (n, hd))


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


def _rope_theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "attn" and cfg.rope_theta_global is not None:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _qkv(params: dict, x: jnp.ndarray, cfg: ModelConfig):
    dt = x.dtype
    q = _split_heads(x @ params["wq"].astype(dt), cfg.n_heads, cfg.hd)
    k = _split_heads(x @ params["wk"].astype(dt), cfg.n_kv_heads, cfg.hd)
    v = _split_heads(x @ params["wv"].astype(dt), cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    return q, k, v


def attention_fwd(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                  kind: str, positions: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence causal attention.  x: (B, S, d)."""
    q, k, v = _qkv(params, x, cfg)
    theta = _rope_theta(cfg, kind)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    window = cfg.window if kind in ("local", "swa") else 0
    out = kops.attention(q, k, v, causal=True, window=window)
    return _merge_heads(out) @ params["wo"].astype(x.dtype)


def cross_attention_fwd(params: dict, x: jnp.ndarray, media: jnp.ndarray,
                        cfg: ModelConfig) -> jnp.ndarray:
    """Cross-attention: queries from text x (B,S,d), keys/values from
    projected frontend tokens media (B,N,d).  Tanh-gated (llama-vision)."""
    dt = x.dtype
    q = _split_heads(x @ params["wq"].astype(dt), cfg.n_heads, cfg.hd)
    k = _split_heads(media @ params["wk"].astype(dt), cfg.n_kv_heads, cfg.hd)
    v = _split_heads(media @ params["wv"].astype(dt), cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    out = kops.attention(q, k, v, causal=False, window=0)
    out = _merge_heads(out) @ params["wo"].astype(dt)
    return jnp.tanh(params["gate"].astype(jnp.float32)).astype(dt) * out


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                  stack: tuple[int, ...] = (), abstract: bool = False):
    """Cache layout: k/v (stack..., B, L, n_kv, hd); L is a ring buffer for
    windowed kinds.  Activation logical axes: batch / kv_seq / kv_heads."""
    L = cache_len if kind in ("attn", ) else min(cfg.window or cache_len,
                                                 cache_len)
    shape = stack + (batch, L, cfg.n_kv_heads, cfg.hd)
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, cfg.dtype)
    else:
        arr = jnp.zeros(shape, cfg.dtype)
    return {"k": arr, "v": arr}


def kv_cache_axes(kind: str, stack_dims: int = 0):
    lead = ("layers",) * stack_dims
    ax = lead + ("batch", "kv_seq", "kv_heads", None)
    return {"k": ax, "v": ax}


def _ring_valid(pos: jnp.ndarray, L: int, cfg: ModelConfig,
                kind: str) -> jnp.ndarray:
    """Live-slot mask of a ring cache: entry at index i holds absolute
    position p with p % L == i, p <= pos, p > pos - L.  pos: scalar -> (L,);
    pos: (B,) -> (B, L) per-sequence masks."""
    idx = jnp.arange(L)
    if pos.ndim:
        pos = pos[:, None]
    abs_pos = pos - jnp.mod(pos - idx, L)       # absolute position per slot
    valid = (abs_pos >= 0) & (abs_pos >= pos - (L - 1))
    if kind in ("local", "swa") and cfg.window:
        valid &= abs_pos > pos - cfg.window
    return valid


def attention_decode(params: dict, x: jnp.ndarray, cache: dict,
                     cfg: ModelConfig, kind: str,
                     pos: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """One-token decode.  x: (B, 1, d); pos: scalar int32, or (B,) int32
    when every sequence sits at its own position (continuous batching).
    Returns (out (B,1,d), updated cache)."""
    q, k_new, v_new = _qkv(params, x, cfg)
    theta = _rope_theta(cfg, kind)
    B = x.shape[0]
    posb = jnp.broadcast_to(pos[None], (B,)) if pos.ndim == 0 else pos
    q = apply_rope(q, posb[:, None], theta)
    k_new = apply_rope(k_new, posb[:, None], theta)

    L = cache["k"].shape[1]
    if pos.ndim == 0:
        slot = jnp.mod(pos, L)                  # ring buffer for windowed
        k = _dyn_update(cache["k"], k_new, slot)
        v = _dyn_update(cache["v"], v_new, slot)
    else:
        slot = jnp.mod(posb, L)                 # (B,) per-sequence slots
        b_idx = jnp.arange(B)
        k = cache["k"].at[b_idx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[b_idx, slot].set(v_new[:, 0].astype(cache["v"].dtype))

    valid = _ring_valid(pos, L, cfg, kind)
    out = kops.attention_decode(q, k, v, valid)
    out = _merge_heads(out) @ params["wo"].astype(x.dtype)
    return out, {"k": k, "v": v}


def attention_decode_paged(params: dict, x: jnp.ndarray, cache: dict,
                           cfg: ModelConfig, kind: str,
                           pos: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """One-token decode against a paged KV cache (repro.serve.paged_cache).

    cache: {"pk", "pv": (P, page, KV, hd) page pools shared by all sequence
    slots, "pt": (B, n_pp) int32 per-sequence page table}.  pos: (B,) int32
    per-sequence positions (slots not serving a sequence should sit at
    pos 0 — their page-table rows point at the reserved junk page, so the
    write below never touches live pages).

    The logical ring view (slot = pos % L, L = n_pp * page) is identical to
    the dense cache's, so paged decode is exactly dense decode with the
    cache rows indirected through the page table.
    """
    q, k_new, v_new = _qkv(params, x, cfg)
    theta = _rope_theta(cfg, kind)
    B = x.shape[0]
    posb = jnp.broadcast_to(pos[None], (B,)) if pos.ndim == 0 else pos
    q = apply_rope(q, posb[:, None], theta)
    k_new = apply_rope(k_new, posb[:, None], theta)

    pk, pv, pt = cache["pk"], cache["pv"], cache["pt"]
    page = pk.shape[1]
    L = pt.shape[1] * page
    slot = jnp.mod(posb, L)                                   # (B,)
    phys = jnp.take_along_axis(pt, (slot // page)[:, None], axis=1)[:, 0]
    off = slot % page
    pk = pk.at[phys, off].set(k_new[:, 0].astype(pk.dtype))
    pv = pv.at[phys, off].set(v_new[:, 0].astype(pv.dtype))

    k = kops.page_gather(pk, pt)                              # (B, L, KV, hd)
    v = kops.page_gather(pv, pt)
    valid = _ring_valid(posb, L, cfg, kind)
    out = kops.attention_decode(q, k, v, valid)
    out = _merge_heads(out) @ params["wo"].astype(x.dtype)
    return out, {"pk": pk, "pv": pv, "pt": pt}


def attention_prefill_paged(params: dict, x: jnp.ndarray, cache: dict,
                            cfg: ModelConfig, kind: str,
                            start: jnp.ndarray) -> tuple[jnp.ndarray, dict]:
    """One prompt chunk against a paged KV cache (chunked prefill).

    x: (1, C, d) — chunk tokens at absolute positions start..start+C-1
    (start: scalar int32); cache: {"pk", "pv": (P, page, KV, hd) pools,
    "pt": (1, n_pp) page-table row of the slot being prefilled}.  Prior
    chunks' K/V are read through the page table (gather *before* the
    chunk's own K/V are scattered in, so windowed rings that wrap within
    this chunk still see the pre-wrap entries they legitimately cover),
    masked by absolute position exactly like a full-sequence causal
    prefill.  C must not exceed the ring length L (the serve engine caps
    chunk size at the smallest page-class L so scatter slots are unique).
    Returns (out (1, C, d), updated cache).
    """
    q, k_new, v_new = _qkv(params, x, cfg)
    theta = _rope_theta(cfg, kind)
    C = x.shape[1]
    q_pos = (start + jnp.arange(C, dtype=jnp.int32))[None]    # (1, C)
    q = apply_rope(q, q_pos, theta)
    k_new = apply_rope(k_new, q_pos, theta)

    pk, pv, pt = cache["pk"], cache["pv"], cache["pt"]
    page = pk.shape[1]
    L = pt.shape[1] * page
    # absolute position held by each ring slot before this chunk lands:
    # the newest prior entry is start-1, slot i holds last - (last-i) % L
    idx = jnp.arange(L, dtype=jnp.int32)
    last = start.astype(jnp.int32) - 1
    abs_pos = last - jnp.mod(last - idx, L)
    ctx_pos = jnp.where(abs_pos >= 0, abs_pos, -1)[None]      # (1, L)

    k_ctx = kops.page_gather(pk, pt)                          # (1, L, KV, hd)
    v_ctx = kops.page_gather(pv, pt)
    window = cfg.window if kind in ("local", "swa") else 0
    out = kops.prefill_page_attention(q, k_ctx, v_ctx, k_new, v_new,
                                      ctx_pos, q_pos, window=window)

    slot = jnp.mod(q_pos[0], L)                               # (C,)
    phys = pt[0, slot // page]
    off = slot % page
    pk = pk.at[phys, off].set(k_new[0].astype(pk.dtype))
    pv = pv.at[phys, off].set(v_new[0].astype(pv.dtype))
    out = _merge_heads(out) @ params["wo"].astype(x.dtype)
    return out, {"pk": pk, "pv": pv, "pt": pt}


def _dyn_update(buf: jnp.ndarray, new: jnp.ndarray,
                slot: jnp.ndarray) -> jnp.ndarray:
    """Write the (B,1,n_kv,hd) entry at ring index ``slot`` along axis 1.

    Two lowerings:
      * default: dynamic_update_slice — minimal HBM traffic, but under a
        kv_seq-sharded cache GSPMD cannot partition a scatter at a dynamic
        index and falls back to full rematerialization (replicate + reshard
        = a giant collective per decode step);
      * REPRO_ONEHOT_CACHE=1: select(iota == slot, new, buf) — elementwise,
        partitions perfectly along the sharded seq dim; costs one read+write
        of the cache instead of a collective.  See EXPERIMENTS.md §Perf.
    """
    import os as _os
    if _os.environ.get("REPRO_ONEHOT_CACHE") == "1":
        L = buf.shape[1]
        hit = (jnp.arange(L, dtype=jnp.int32) ==
               slot.astype(jnp.int32))[None, :, None, None]
        return jnp.where(hit, new.astype(buf.dtype), buf)
    start = (jnp.zeros((), slot.dtype), slot.astype(jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), start)
