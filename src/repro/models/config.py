"""Model configuration shared by all architectures in the zoo.

A model is a list of **block groups**; each group is a repeating pattern of
layer kinds applied ``n`` times via ``jax.lax.scan`` over stacked parameters
(small HLO, fast compile, remat-friendly).  Layer kinds:

  attn    — global causal self-attention (GQA)
  local   — sliding-window causal self-attention (bounded KV)
  swa     — alias of local (mixtral-style sliding window)
  xattn   — cross-attention to modality tokens (vision frontend stub)
  rwkv6   — RWKV-6 token-shift + data-dependent-decay WKV mixer
  rglru   — Griffin RG-LRU recurrent block (conv1d + gated linear recurrence)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

ATTN_KINDS = ("attn", "local", "swa", "xattn")
RECURRENT_KINDS = ("rwkv6", "rglru")


@dataclasses.dataclass(frozen=True)
class BlockGroup:
    pattern: tuple[str, ...]   # layer kinds within one scanned super-block
    n: int                     # scan length (number of pattern repetitions)

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.n


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    groups: tuple[BlockGroup, ...]
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None       # default d_model // n_heads
    window: int = 0                   # sliding window for local/swa kinds
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None   # gemma3: distinct global theta
    norm: str = "rmsnorm"             # rmsnorm | layernorm | layernorm_np
    qk_norm: bool = False             # gemma3-style per-head q/k rmsnorm
    mlp: str = "swiglu"               # swiglu | geglu | gelu
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma: scale embeddings by sqrt(d)
    logit_softcap: float = 0.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024        # dispatch group size (tokens)
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # --- recurrent (rwkv6 / rglru) ---
    d_rnn: int = 0                    # rglru recurrence width (default d_model)
    conv_width: int = 4               # rglru temporal conv width
    decay_lora: int = 64              # rwkv6 data-dependent decay rank
    # --- modality frontend stubs ---
    frontend: str | None = None       # None | "vision" | "audio_tokens"
    n_frontend_tokens: int = 0        # e.g. vision patch count
    d_frontend: int = 0               # raw patch embedding width
    # --- numerics / training ---
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32    # master copy
    max_seq: int = 8192
    # --- shape-cell policy ---
    long_context: bool | None = None  # run long_500k? None = derive
    # --- notes for DESIGN.md traceability ---
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return sum(g.n_layers for g in self.groups)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        out: list[str] = []
        for g in self.groups:
            out.extend(g.pattern * g.n)
        return tuple(out)

    def kv_cache_len(self, kind: str, seq_len: int) -> int:
        """Per-layer KV length needed to decode with ``seq_len`` context."""
        if kind in ("local", "swa"):
            return min(self.window, seq_len) if self.window else seq_len
        return seq_len

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer needs an unbounded full-attention KV cache —
        the criterion for running the long_500k shape cell."""
        kinds = set(self.layer_kinds)
        if "attn" in kinds or "xattn" in kinds:
            return False
        return all(k in ("rwkv6", "rglru") or
                   (k in ("local", "swa") and self.window > 0)
                   for k in kinds)

    @property
    def runs_long_context(self) -> bool:
        """Whether the long_500k shape cell applies (see DESIGN.md §5)."""
        if self.long_context is not None:
            return self.long_context
        return self.sub_quadratic

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0 or self.head_dim, self.name
        assert self.n_heads % self.n_kv_heads == 0, self.name
        if self.is_moe:
            assert 0 < self.top_k <= self.n_experts, self.name
        for g in self.groups:
            for k in g.pattern:
                assert k in ATTN_KINDS + RECURRENT_KINDS, (self.name, k)
                if k in ("local", "swa"):
                    assert self.window > 0, self.name
        if self.frontend == "vision":
            assert self.n_frontend_tokens > 0 and self.d_frontend > 0
