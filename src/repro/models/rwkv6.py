"""RWKV-6 (Finch) block: token-shift time mix with data-dependent decay WKV,
plus the squared-ReLU channel mix.  arXiv:2404.05892.

Faithful pieces: data-dependent decay w_t = exp(-exp(base + LoRA(x_t))),
current-token bonus u, (hd,hd) per-head state, gated output with group-norm,
token-shift on every projection input, squared-relu channel mix.
Simplification (documented in DESIGN.md): token-shift mixing coefficients are
static per-channel (RWKV-5 style) rather than the full data-dependent ddlerp;
the decay — the headline Finch feature — keeps its data dependence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rmsnorm
from .paramlib import P
from ..kernels import ops as kops


def rwkv6_specs(cfg: ModelConfig, stack: tuple[int, ...] = ()) -> dict:
    lead = ("layers",) * len(stack)
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    r_lo = cfg.decay_lora
    tm = {
        # token-shift mix coefficients (static lerp weights in [0,1] via
        # sigmoid at apply time)
        "mu_r": P(stack + (d,), lead + (None,), init="zeros"),
        "mu_k": P(stack + (d,), lead + (None,), init="zeros"),
        "mu_v": P(stack + (d,), lead + (None,), init="zeros"),
        "mu_w": P(stack + (d,), lead + (None,), init="zeros"),
        "mu_g": P(stack + (d,), lead + (None,), init="zeros"),
        "wr": P(stack + (d, H * hd), lead + ("embed", "heads")),
        "wk": P(stack + (d, H * hd), lead + ("embed", "heads")),
        "wv": P(stack + (d, H * hd), lead + ("embed", "heads")),
        "wg": P(stack + (d, H * hd), lead + ("embed", "heads")),
        "wo": P(stack + (H * hd, d), lead + ("heads", "embed")),
        # data-dependent decay: w_t = exp(-exp(decay_base + x W1 W2))
        "decay_base": P(stack + (H, hd), lead + (None, None), init="zeros"),
        "decay_w1": P(stack + (d, r_lo), lead + ("embed", None), scale=0.01),
        "decay_w2": P(stack + (r_lo, H * hd), lead + (None, "heads"),
                      scale=0.01),
        "bonus_u": P(stack + (H, hd), lead + (None, None), scale=0.1),
        "gn_scale": P(stack + (H, hd), lead + (None, None), init="ones"),
    }
    cm = {
        "mu_ck": P(stack + (d,), lead + (None,), init="zeros"),
        "mu_cr": P(stack + (d,), lead + (None,), init="zeros"),
        "ck": P(stack + (d, cfg.d_ff), lead + ("embed", "ffn")),
        "cv": P(stack + (cfg.d_ff, d), lead + ("ffn", "embed")),
        "cr": P(stack + (d, d), lead + ("embed", "embed2")),
    }
    return {"time": tm, "chan": cm}


def _token_shift(x: jnp.ndarray, x_prev_last: jnp.ndarray | None,
                 mu: jnp.ndarray) -> jnp.ndarray:
    """lerp(x_t, x_{t-1}, sigmoid(mu)).  x: (B, T, d).
    x_prev_last: (B, d) carry from the previous chunk (decode), else zeros."""
    if x_prev_last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)
    m = jax.nn.sigmoid(mu.astype(jnp.float32)).astype(x.dtype)
    return x + m * (prev - x)


def _time_mix_inputs(tp: dict, x: jnp.ndarray, cfg: ModelConfig,
                     x_last: jnp.ndarray | None):
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    dt = x.dtype

    def proj(mu, w):
        return (_token_shift(x, x_last, mu) @ w.astype(dt)) \
            .reshape(B, T, H, hd)

    r = proj(tp["mu_r"], tp["wr"])
    k = proj(tp["mu_k"], tp["wk"])
    v = proj(tp["mu_v"], tp["wv"])
    g = proj(tp["mu_g"], tp["wg"])
    xw = _token_shift(x, x_last, tp["mu_w"])
    dlo = (xw @ tp["decay_w1"].astype(dt)) @ tp["decay_w2"].astype(dt)
    dlog = tp["decay_base"].astype(jnp.float32)[None, None] \
        + dlo.reshape(B, T, H, hd).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dlog)).astype(jnp.float32)      # decay in (0,1)
    return r, k, v, g, w


def _finish(tp: dict, y: jnp.ndarray, g: jnp.ndarray, x_dtype,
            cfg: ModelConfig) -> jnp.ndarray:
    B, T, H, hd = y.shape
    y = rmsnorm(y, tp["gn_scale"])                       # per-head group norm
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    return y.reshape(B, T, H * hd).astype(x_dtype) @ tp["wo"].astype(x_dtype)


def time_mix_fwd(tp: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    r, k, v, g, w = _time_mix_inputs(tp, x, cfg, None)
    y = kops.rwkv6(r, k, v, w, tp["bonus_u"])
    return _finish(tp, y, g, x.dtype, cfg)


def time_mix_decode(tp: dict, x: jnp.ndarray, state: dict,
                    cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """x: (B, 1, d); state: {'S': (B,H,hd,hd) f32, 'x_last': (B, d)}."""
    r, k, v, g, w = _time_mix_inputs(tp, x, cfg, state["x_last"])
    y, S1 = kops.rwkv6_stateful(r, k, v, w, tp["bonus_u"], state["S"])
    out = _finish(tp, y, g, x.dtype, cfg)
    return out, {"S": S1, "x_last": x[:, -1]}


def chan_mix_fwd(cp: dict, x: jnp.ndarray, cfg: ModelConfig,
                 x_last: jnp.ndarray | None = None) -> jnp.ndarray:
    dt = x.dtype
    xk = _token_shift(x, x_last, cp["mu_ck"])
    xr = _token_shift(x, x_last, cp["mu_cr"])
    kk = jnp.square(jax.nn.relu(xk @ cp["ck"].astype(dt)))
    return jax.nn.sigmoid((xr @ cp["cr"].astype(dt)).astype(jnp.float32)) \
        .astype(dt) * (kk @ cp["cv"].astype(dt))


def init_rwkv_state(cfg: ModelConfig, batch: int,
                    stack: tuple[int, ...] = (), abstract: bool = False):
    H, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    shapes = {
        "S": (stack + (batch, H, hd, hd), jnp.float32),
        "x_last": (stack + (batch, d), cfg.dtype),
        "cx_last": (stack + (batch, d), cfg.dtype),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, t) for k, (s, t) in shapes.items()}
    return {k: jnp.zeros(s, t) for k, (s, t) in shapes.items()}


def rwkv_state_axes(stack_dims: int = 0):
    lead = ("layers",) * stack_dims
    return {"S": lead + ("batch", "heads_act", None, None),
            "x_last": lead + ("batch", None),
            "cx_last": lead + ("batch", None)}
