"""Shared neural building blocks: norms, RoPE, embeddings, gated MLPs.

All forwards take an explicit params dict (pure functions), compute norms and
softmaxes in float32, and return activations in the model compute dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .paramlib import P


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, stack: tuple[int, ...] = ()) -> dict:
    """Parameter specs for one norm layer (possibly scan-stacked)."""
    lead_axes = ("layers",) * len(stack)
    if cfg.norm == "layernorm_np":      # olmo: non-parametric — no params
        return {}
    d = {"scale": P(stack + (cfg.d_model,), lead_axes + (None,), init="ones")}
    if cfg.norm == "layernorm":
        d["bias"] = P(stack + (cfg.d_model,), lead_axes + (None,), init="zeros")
    return d


def apply_norm(params: dict, x: jnp.ndarray, cfg: ModelConfig,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf * rms
        if params:
            out = out * params["scale"].astype(jnp.float32)
        return out.astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        out = out * params["scale"].astype(jnp.float32) \
            + params["bias"].astype(jnp.float32)
    # layernorm_np (olmo): no affine transform
    return out.astype(x.dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray | None = None,
            eps: float = 1e-6) -> jnp.ndarray:
    """Standalone rmsnorm (qk-norm, rwkv group-norm) in f32."""
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    out = xf * rms
    if scale is not None:
        out = out * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (.., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (.., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, stack: tuple[int, ...] = ()) -> dict:
    lead = ("layers",) * len(stack)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wg": P(stack + (d, f), lead + ("embed", "ffn")),
            "wu": P(stack + (d, f), lead + ("embed", "ffn")),
            "wd": P(stack + (f, d), lead + ("ffn", "embed")),
        }
    return {  # plain gelu MLP
        "wu": P(stack + (d, f), lead + ("embed", "ffn")),
        "wd": P(stack + (f, d), lead + ("ffn", "embed")),
    }


def apply_mlp(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        g = act(x @ params["wg"].astype(x.dtype))
        u = x @ params["wu"].astype(x.dtype)
        return (g * u) @ params["wd"].astype(x.dtype)
    h = jax.nn.gelu(x @ params["wu"].astype(x.dtype), approximate=True)
    return h @ params["wd"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    # std 0.02 (GPT-2 convention): with a tied LM head the logit variance is
    # d_model * std^2 — std 1.0 would give ~sqrt(d) logits and a wildly
    # inflated initial loss
    specs = {"embedding": P((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                            scale=0.02)}
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return specs


def embed_tokens(params: dict, tokens: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    x = params["embedding"].astype(cfg.dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    return x


def lm_logits(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embedding"].astype(cfg.dtype).T
    else:
        w = params["lm_head"].astype(cfg.dtype)
    logits = x @ w
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
        return logits
    return logits.astype(jnp.float32)
