"""Model zoo: composable block-group transformers + the paper's own model.

  config      — ModelConfig / BlockGroup
  paramlib    — P-spec trees (init / abstract / axes from one source)
  layers      — norms, RoPE, MLPs, embeddings
  attention   — GQA / sliding-window / cross attention + KV caches
  moe         — grouped einsum top-k mixture of experts
  rwkv6       — RWKV-6 time mix / channel mix
  rglru       — Griffin RG-LRU recurrent block
  transformer — composition: forward / lm_loss / prefill / decode_step
  regression  — the paper's linear-regression prototype task
"""
from .config import BlockGroup, ModelConfig  # noqa: F401
from . import paramlib, transformer  # noqa: F401
