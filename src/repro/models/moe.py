"""Mixture-of-Experts FFN with grouped einsum dispatch (GShard/Switch style).

TPU-idiomatic dense dispatch: tokens are split into groups; within a group a
top-k router assigns tokens to experts subject to a per-expert capacity, and
dispatch/combine are one-hot einsums (MXU-friendly, static shapes — no
scatter).  Expert parallelism: the ``experts`` logical axis shards over the
``model`` mesh axis when divisible (llama4-scout: 16e over 16-way); otherwise
experts stay replicated and their ``ffn`` dim tensor-shards (mixtral: 8e).

Aux losses follow Switch Transformer: load-balance (E * sum_e f_e * P_e) and
router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .paramlib import P
from ..kernels import ops as kops


def moe_specs(cfg: ModelConfig, stack: tuple[int, ...] = ()) -> dict:
    lead = ("layers",) * len(stack)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": P(stack + (d, E), lead + ("embed", None), scale=0.02),
        "wg": P(stack + (E, d, f), lead + ("experts", "embed", "ffn")),
        "wu": P(stack + (E, d, f), lead + ("experts", "embed", "ffn")),
        "wd": P(stack + (E, f, d), lead + ("experts", "ffn", "embed")),
    }


def _group_tokens(x: jnp.ndarray, group_size: int) -> tuple[jnp.ndarray, int]:
    """(B, S, d) -> (G, g, d).  Group size adapts down for small inputs.

    REPRO_MOE_GROUP overrides the configured size: the dispatch/combine
    one-hot tensors are (G, g, E, C) with E*C = g*k*cf, i.e. their footprint
    and HBM traffic scale LINEARLY with g — a smaller group trades a little
    routing imbalance for an 8-16x cut in dispatch memory (§Perf)."""
    import os as _os
    if _os.environ.get("REPRO_MOE_GROUP"):
        group_size = int(_os.environ["REPRO_MOE_GROUP"])
    B, S, d = x.shape
    T = B * S
    g = min(group_size, T)
    while T % g != 0:          # static-shape friendly divisor
        g -= 1
    return x.reshape(T // g, g, d), g


def moe_ffn(params: dict, x: jnp.ndarray,
            cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """Returns (output (B,S,d), aux {lb_loss, z_loss, router_entropy})."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xg, g = _group_tokens(x, cfg.moe_group_size)
    G = xg.shape[0]

    logits = jnp.einsum("Ggd,dE->GgE", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection, one iteration per k (k is 1 or 2 in the zoo)
    import math
    capacity = max(math.ceil(g * k * cfg.capacity_factor / E), 1)
    remaining = probs
    combine = jnp.zeros((G, g, E, capacity), jnp.float32)
    dispatch = jnp.zeros((G, g, E, capacity), bool)
    fill = jnp.zeros((G, E), jnp.int32)    # tokens already routed per expert
    for _ in range(k):
        gate, idx = jax.lax.top_k(remaining, 1)          # (G, g, 1)
        gate, idx = gate[..., 0], idx[..., 0]            # (G, g)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)   # (G, g, E)
        pos = fill[:, None, :] + (jnp.cumsum(onehot, axis=1)
                                  - onehot).astype(jnp.int32)  # (G, g, E)
        keep = onehot.astype(bool) & (pos < capacity)
        slot = jax.nn.one_hot(jnp.where(keep, pos, capacity),
                              capacity, dtype=jnp.float32)     # (G,g,E,C)
        slot = slot * keep[..., None]
        dispatch |= slot.astype(bool)
        combine = combine + slot * gate[..., None, None]
        fill = fill + jnp.sum(onehot, axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)

    # renormalize combine weights over selected experts (mixtral convention)
    denom = jnp.sum(combine, axis=(2, 3), keepdims=True)
    combine = combine / jnp.maximum(denom, 1e-9)

    def _ep(t):
        """Expert-parallel layout constraint (REPRO_MOE_EP_CONSTRAINT=1):
        pin the leading expert dim of dispatch intermediates to the `model`
        mesh axis so GSPMD routes tokens with all-to-alls instead of
        all-reducing dense dispatch tensors (GShard layout).  Only active
        when experts divide the axis (llama4: 16e / 16-way)."""
        import os as _os
        if _os.environ.get("REPRO_MOE_EP_CONSTRAINT") == "1" \
                and cfg.n_experts % 16 == 0:
            from jax.sharding import PartitionSpec as _PS
            # (E, G, C, d): experts over `model`, token groups over `data`
            spec = _PS("model", "data", *((None,) * (t.ndim - 2)))
            return jax.lax.with_sharding_constraint(t, spec)
        return t

    out = kops.moe_grouped_ffn(dispatch, combine, xg,
                               params["wg"].astype(xg.dtype),
                               params["wu"].astype(xg.dtype),
                               params["wd"].astype(xg.dtype), ep=_ep)

    # Switch-style aux losses
    me = jnp.mean(probs, axis=(0, 1))                        # avg router prob
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), E, dtype=jnp.float32)
    ce = jnp.mean(top1, axis=(0, 1))                         # token fraction
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "router_entropy": -jnp.mean(jnp.sum(
               probs * jnp.log(probs + 1e-9), axis=-1))}
    return out.reshape(B, S, d), aux
