"""Model composition: block groups scanned over stacked parameters.

Entry points (all pure functions over a params pytree):

  model_specs(cfg)                         -> P-spec tree (single source of truth)
  forward(params, tokens, cfg, ...)        -> (logits, aux)        [train path]
  lm_loss(params, batch, cfg, ...)         -> (loss, metrics)
  prefill(params, tokens, cfg, ...)        -> (last_logits, cache) [serve path]
  decode_step(params, cache, tokens, pos, cfg, ...) -> (logits, cache)
  init_cache(cfg, batch, cache_len, ...)   -> cache pytree (+ axes via cache_axes)

Layers are grouped into scan super-blocks (ModelConfig.groups); parameters of
each slot are stacked (n, ...) so the HLO contains one unrolled pattern per
group regardless of depth — this is what keeps 48-layer configs compilable
on the CPU dry-run host and gives remat a natural boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv6 as rwkv_mod
from .config import BlockGroup, ModelConfig
from .layers import (apply_mlp, apply_norm, embed_specs, embed_tokens,
                     lm_logits, mlp_specs, norm_specs)
from .paramlib import P

AUX_ZERO = {"lb_loss": 0.0, "z_loss": 0.0, "router_entropy": 0.0}


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _block_specs(cfg: ModelConfig, kind: str, stack: tuple[int, ...]) -> dict:
    d: dict[str, Any] = {"ln1": norm_specs(cfg, stack)}
    if kind in ("attn", "local", "swa", "xattn"):
        d["mix"] = attn.attn_specs(cfg, kind, stack)
    elif kind == "rwkv6":
        both = rwkv_mod.rwkv6_specs(cfg, stack)
        d["mix"] = both["time"]
        d["ln2"] = norm_specs(cfg, stack)
        d["ffn"] = both["chan"]
        return d
    elif kind == "rglru":
        d["mix"] = rglru_mod.rglru_specs(cfg, stack)
    else:
        raise ValueError(kind)
    d["ln2"] = norm_specs(cfg, stack)
    if cfg.is_moe and kind != "xattn":
        d["ffn"] = moe_mod.moe_specs(cfg, stack)
    else:
        d["ffn"] = mlp_specs(cfg, stack)
    return d


def model_specs(cfg: ModelConfig) -> dict:
    cfg.validate()
    specs: dict[str, Any] = dict(embed_specs(cfg))
    if cfg.frontend == "vision":
        specs["frontend_proj"] = P((cfg.d_frontend, cfg.d_model),
                                   (None, "embed"))
    specs["groups"] = {
        f"g{gi}": {f"s{si}": _block_specs(cfg, kind, (g.n,))
                   for si, kind in enumerate(g.pattern)}
        for gi, g in enumerate(cfg.groups)}
    specs["final_norm"] = norm_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# Block application (shared by train/prefill/decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ctx:
    positions: jnp.ndarray            # (B, S) absolute positions
    media: jnp.ndarray | None = None  # (B, N, d) projected frontend tokens


def _wsc(x: jnp.ndarray, act_specs: dict | None, name: str) -> jnp.ndarray:
    """Optional activation sharding constraint (SPMD path only)."""
    if act_specs and name in act_specs:
        return jax.lax.with_sharding_constraint(x, act_specs[name])
    return x


def _apply_mix(bp: dict, kind: str, h: jnp.ndarray, cfg: ModelConfig,
               ctx: Ctx) -> jnp.ndarray:
    if kind in ("attn", "local", "swa"):
        return attn.attention_fwd(bp["mix"], h, cfg, kind, ctx.positions)
    if kind == "xattn":
        return attn.cross_attention_fwd(bp["mix"], h, ctx.media, cfg)
    if kind == "rwkv6":
        return rwkv_mod.time_mix_fwd(bp["mix"], h, cfg)
    if kind == "rglru":
        return rglru_mod.rglru_fwd(bp["mix"], h, cfg)
    raise ValueError(kind)


def _apply_ffn(bp: dict, kind: str, h: jnp.ndarray,
               cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    if kind == "rwkv6":
        return rwkv_mod.chan_mix_fwd(bp["ffn"], h, cfg), dict(AUX_ZERO)
    if cfg.is_moe and kind != "xattn":
        return moe_mod.moe_ffn(bp["ffn"], h, cfg)
    return apply_mlp(bp["ffn"], h, cfg), dict(AUX_ZERO)


def _apply_block(bp: dict, kind: str, x: jnp.ndarray, cfg: ModelConfig,
                 ctx: Ctx) -> tuple[jnp.ndarray, dict]:
    h = apply_norm(bp["ln1"], x, cfg)
    x = x + _apply_mix(bp, kind, h, cfg, ctx)
    h2 = apply_norm(bp["ln2"], x, cfg)
    f, aux = _apply_ffn(bp, kind, h2, cfg)
    return x + f, aux


def _merge_aux(acc: dict, new: dict) -> dict:
    return {k: acc[k] + new[k] for k in acc}


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------

def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(f"unknown remat policy {remat!r}")


def forward(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
            media: jnp.ndarray | None = None,
            remat: str = "none",
            act_specs: dict | None = None) -> tuple[jnp.ndarray, dict]:
    """Full-sequence forward.  tokens: (B, S) int32 -> logits (B, S, V) f32."""
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    x = _wsc(x, act_specs, "act")
    ctx = Ctx(positions=jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
    if cfg.frontend == "vision":
        ctx.media = media.astype(cfg.dtype) @ \
            params["frontend_proj"].astype(cfg.dtype)

    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_ZERO}
    for gi, g in enumerate(cfg.groups):
        gp = params["groups"][f"g{gi}"]

        def body(carry, slot_params, _g=g):
            xc, auxc = carry
            xc = _wsc(xc, act_specs, "act")
            for si, kind in enumerate(_g.pattern):
                xc, a = _apply_block(slot_params[f"s{si}"], kind, xc, cfg, ctx)
                auxc = _merge_aux(auxc, {k: jnp.asarray(v, jnp.float32)
                                         for k, v in a.items()})
            return (xc, auxc), None

        (x, aux), _ = jax.lax.scan(_remat_wrap(body, remat), (x, aux), gp)

    x = apply_norm(params["final_norm"], x, cfg)
    return _wsc(lm_logits(params, x, cfg), act_specs, "logits"), aux


def _forward_hidden(params, tokens, cfg, media=None, remat="none",
                    act_specs=None):
    """forward() without the final norm / LM head (used by chunked CE)."""
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    x = _wsc(x, act_specs, "act")
    ctx = Ctx(positions=jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
    if cfg.frontend == "vision":
        ctx.media = media.astype(cfg.dtype) @ \
            params["frontend_proj"].astype(cfg.dtype)
    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_ZERO}
    for gi, g in enumerate(cfg.groups):
        gp = params["groups"][f"g{gi}"]

        def body(carry, slot_params, _g=g):
            xc, auxc = carry
            xc = _wsc(xc, act_specs, "act")
            for si, kind in enumerate(_g.pattern):
                xc, a = _apply_block(slot_params[f"s{si}"], kind, xc, cfg,
                                     ctx)
                auxc = _merge_aux(auxc, {k: jnp.asarray(v, jnp.float32)
                                         for k, v in a.items()})
            return (xc, auxc), None

        (x, aux), _ = jax.lax.scan(_remat_wrap(body, remat), (x, aux), gp)
    return x, aux


def _lm_loss_chunked(params: dict, batch: dict, cfg: ModelConfig,
                     remat: str, act_specs: dict | None,
                     n_chunks: int = 8) -> tuple[jnp.ndarray, dict]:
    """CE computed over sequence chunks: the (B, S, V) logits / one-hot
    tensors never materialize — peak loss-block memory drops by n_chunks at
    the cost of scanning the LM-head projection (beyond-paper optimization;
    see EXPERIMENTS.md §Perf)."""
    B, S = batch["tokens"].shape
    hidden, aux = _forward_hidden(params, batch["tokens"], cfg,
                                  media=batch.get("media"), remat=remat,
                                  act_specs=act_specs)
    x = apply_norm(params["final_norm"], hidden, cfg)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n_chunks = min(n_chunks, S)
    while S % n_chunks != 0:
        n_chunks -= 1
    c = S // n_chunks
    xs = jnp.moveaxis(x.reshape(B, n_chunks, c, x.shape[-1]), 1, 0)
    ls = jnp.moveaxis(batch["labels"].reshape(B, n_chunks, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n_chunks, c), 1, 0)

    def chunk(carry, inp):
        xc, lc, mc = inp
        logits = lm_logits(params, xc, cfg).astype(jnp.float32)
        logits = _wsc(logits, act_specs, "logits")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, cfg.vocab_size, dtype=jnp.float32)
        onehot = _wsc(onehot, act_specs, "logits")
        nll = lse - jnp.sum(logits * onehot, axis=-1)
        tot, cnt = carry
        mf = mc.astype(jnp.float32)
        return (tot + jnp.sum(nll * mf), cnt + jnp.sum(mf)), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ls, ms))
    loss = tot / jnp.maximum(cnt, 1.0)
    n_moe = sum(1 for k in cfg.layer_kinds if k != "xattn") \
        if cfg.is_moe else 1
    total = (loss
             + cfg.router_aux_coef * aux["lb_loss"] / n_moe
             + cfg.router_z_coef * aux["z_loss"] / n_moe)
    metrics = {"loss": loss, "total_loss": total,
               "lb_loss": aux["lb_loss"] / n_moe,
               "router_entropy": aux["router_entropy"] / n_moe}
    return total, metrics


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            remat: str = "none",
            act_specs: dict | None = None) -> tuple[jnp.ndarray, dict]:
    """Next-token cross entropy (one-hot formulation: partitions cleanly
    under vocab sharding).  batch: tokens (B,S), labels (B,S), mask (B,S)."""
    import os as _os
    if _os.environ.get("REPRO_CHUNKED_CE") == "1":
        return _lm_loss_chunked(params, batch, cfg, remat, act_specs)
    logits, aux = forward(params, batch["tokens"], cfg,
                          media=batch.get("media"), remat=remat,
                          act_specs=act_specs)
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)            # (B, S)
    onehot = jax.nn.one_hot(batch["labels"], cfg.vocab_size,
                            dtype=jnp.float32)
    onehot = _wsc(onehot, act_specs, "logits")
    correct = jnp.sum(logits * onehot, axis=-1)
    nll = lse - correct
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    n_moe = sum(1 for k in cfg.layer_kinds if k != "xattn") if cfg.is_moe else 1
    total = (loss
             + cfg.router_aux_coef * aux["lb_loss"] / n_moe
             + cfg.router_z_coef * aux["z_loss"] / n_moe)
    metrics = {"loss": loss, "total_loss": total,
               "lb_loss": aux["lb_loss"] / n_moe,
               "router_entropy": aux["router_entropy"] / n_moe}
    return total, metrics


# ---------------------------------------------------------------------------
# KV / recurrent caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               abstract: bool = False) -> dict:
    cache: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        slots = {}
        for si, kind in enumerate(g.pattern):
            stack = (g.n,)
            if kind in ("attn", "local", "swa"):
                L = cfg.kv_cache_len(kind, cache_len)
                slots[f"s{si}"] = attn.init_kv_cache(
                    cfg, kind, batch, L, stack, abstract)
            elif kind == "rwkv6":
                slots[f"s{si}"] = rwkv_mod.init_rwkv_state(
                    cfg, batch, stack, abstract)
            elif kind == "rglru":
                slots[f"s{si}"] = rglru_mod.init_rglru_state(
                    cfg, batch, stack, abstract)
            else:                      # xattn: media is re-derived, stateless
                slots[f"s{si}"] = {}
        cache[f"g{gi}"] = slots
    return cache


def cache_axes(cfg: ModelConfig) -> dict:
    axes: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        slots = {}
        for si, kind in enumerate(g.pattern):
            if kind in ("attn", "local", "swa"):
                slots[f"s{si}"] = attn.kv_cache_axes(kind, 1)
            elif kind == "rwkv6":
                slots[f"s{si}"] = rwkv_mod.rwkv_state_axes(1)
            elif kind == "rglru":
                slots[f"s{si}"] = rglru_mod.rglru_state_axes(1)
            else:
                slots[f"s{si}"] = {}
        axes[f"g{gi}"] = slots
    return axes


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _decode_block(bp: dict, kind: str, x: jnp.ndarray, c: dict,
                  cfg: ModelConfig, pos: jnp.ndarray,
                  ctx: Ctx) -> tuple[jnp.ndarray, dict]:
    h = apply_norm(bp["ln1"], x, cfg)
    if kind in ("attn", "local", "swa"):
        if "pk" in c:     # paged pool + page table (repro.serve)
            mix, c = attn.attention_decode_paged(bp["mix"], h, c, cfg,
                                                 kind, pos)
        else:
            mix, c = attn.attention_decode(bp["mix"], h, c, cfg, kind, pos)
    elif kind == "xattn":
        mix = attn.cross_attention_fwd(bp["mix"], h, ctx.media, cfg)
    elif kind == "rwkv6":
        mix, tc = rwkv_mod.time_mix_decode(
            bp["mix"], h, {"S": c["S"], "x_last": c["x_last"]}, cfg)
        c = {**c, **tc}
    elif kind == "rglru":
        mix, c = rglru_mod.rglru_decode(bp["mix"], h, c, cfg)
    x = x + mix
    h2 = apply_norm(bp["ln2"], x, cfg)
    if kind == "rwkv6":
        f = rwkv_mod.chan_mix_fwd(bp["ffn"], h2, cfg, x_last=c["cx_last"])
        c = {**c, "cx_last": h2[:, -1]}
    else:
        f, _ = _apply_ffn(bp, kind, h2, cfg)
    return x + f, c


def decode_step(params: dict, cache: dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig,
                media: jnp.ndarray | None = None,
                act_specs: dict | None = None
                ) -> tuple[jnp.ndarray, dict]:
    """One decode step.  tokens: (B, 1); pos: scalar int32, or (B,) int32
    per-sequence positions (continuous batching: every sequence sits at
    its own position; paged caches require the vector form).
    Returns (logits (B, 1, V) f32, updated cache)."""
    B = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)
    x = _wsc(x, act_specs, "act")
    positions = (jnp.broadcast_to(pos[None, None], (B, 1))
                 if pos.ndim == 0 else pos[:, None])
    ctx = Ctx(positions=positions)
    if cfg.frontend == "vision":
        ctx.media = media.astype(cfg.dtype) @ \
            params["frontend_proj"].astype(cfg.dtype)

    new_cache: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        gp = params["groups"][f"g{gi}"]

        def body(xc, slice_, _g=g):
            slot_params, slot_cache = slice_
            new_slots = {}
            for si, kind in enumerate(_g.pattern):
                xc, nc = _decode_block(slot_params[f"s{si}"], kind, xc,
                                       slot_cache[f"s{si}"], cfg, pos, ctx)
                new_slots[f"s{si}"] = nc
            return xc, new_slots

        x, new_g = jax.lax.scan(body, x, (gp, cache[f"g{gi}"]))
        new_cache[f"g{gi}"] = new_g

    x = apply_norm(params["final_norm"], x, cfg)
    return _wsc(lm_logits(params, x, cfg), act_specs, "logits"), new_cache


# ---------------------------------------------------------------------------
# Chunked prefill (repro.serve): one prompt chunk against the paged cache
# ---------------------------------------------------------------------------

def init_chunk_carry(cfg: ModelConfig, batch: int = 1) -> dict:
    """Per-slot recurrent carry for chunked prefill (B=1 per prefilling
    sequence).  Attention layers carry nothing — their state lives in the
    page pools; recurrent layers carry their streaming state *outside*
    the batch cache so interleaved decode steps can't touch it (it is
    written into the cache row only at activation)."""
    carry: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        slots = {}
        for si, kind in enumerate(g.pattern):
            stack = (g.n,)
            if kind == "rwkv6":
                slots[f"s{si}"] = rwkv_mod.init_rwkv_state(cfg, batch, stack)
            elif kind == "rglru":
                slots[f"s{si}"] = rglru_mod.init_rglru_state(cfg, batch,
                                                             stack)
            else:
                slots[f"s{si}"] = {}
        carry[f"g{gi}"] = slots
    return carry


def _chunk_block(bp: dict, kind: str, x: jnp.ndarray, c: dict, car: dict,
                 cfg: ModelConfig, ctx: Ctx, rows: dict, start: jnp.ndarray,
                 cache_len: int) -> tuple[jnp.ndarray, dict, dict]:
    h = apply_norm(bp["ln1"], x, cfg)
    if kind in ("attn", "local", "swa"):
        L = cfg.kv_cache_len(kind, cache_len)
        tmp = {"pk": c["pk"], "pv": c["pv"], "pt": rows[L][None]}
        mix, tmp = attn.attention_prefill_paged(bp["mix"], h, tmp, cfg,
                                                kind, start)
        c = {**c, "pk": tmp["pk"], "pv": tmp["pv"]}
    elif kind == "xattn":
        mix = attn.cross_attention_fwd(bp["mix"], h, ctx.media, cfg)
    elif kind == "rwkv6":
        mix, tc = rwkv_mod.time_mix_decode(
            bp["mix"], h, {"S": car["S"], "x_last": car["x_last"]}, cfg)
        car = {**car, **tc}
    elif kind == "rglru":
        mix, car = rglru_mod.rglru_decode(bp["mix"], h, car, cfg)
    x = x + mix
    h2 = apply_norm(bp["ln2"], x, cfg)
    if kind == "rwkv6":
        f = rwkv_mod.chan_mix_fwd(bp["ffn"], h2, cfg, x_last=car["cx_last"])
        car = {**car, "cx_last": h2[:, -1]}
    else:
        f, _ = _apply_ffn(bp, kind, h2, cfg)
    return x + f, c, car


def prefill_chunk(params: dict, cache: dict, tokens: jnp.ndarray,
                  start: jnp.ndarray, rows: dict, carry: dict,
                  cfg: ModelConfig, cache_len: int
                  ) -> tuple[jnp.ndarray, dict, dict]:
    """Process one prompt chunk of an in-flight prefill against the paged
    cache.  tokens: (1, C) at absolute positions start..start+C-1; rows:
    {L: (n_pp,) int32} the slot's physical pages per page class (the
    batch page table stays on the junk page until activation — decode
    steps interleave freely); carry: ``init_chunk_carry`` pytree.
    Returns (last-position logits (1, V), cache, carry)."""
    B, C = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    q_pos = (start.astype(jnp.int32) + jnp.arange(C, dtype=jnp.int32))[None]
    ctx = Ctx(positions=jnp.broadcast_to(q_pos, (B, C)))

    new_cache: dict[str, Any] = {}
    new_carry: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        gp = params["groups"][f"g{gi}"]

        def body(xc, slice_, _g=g):
            sp, sc, scar = slice_
            new_slots, new_cars = {}, {}
            for si, kind in enumerate(_g.pattern):
                xc, nc, ncar = _chunk_block(sp[f"s{si}"], kind, xc,
                                            sc[f"s{si}"], scar[f"s{si}"],
                                            cfg, ctx, rows, start, cache_len)
                new_slots[f"s{si}"] = nc
                new_cars[f"s{si}"] = ncar
            return xc, (new_slots, new_cars)

        x, (cg, carg) = jax.lax.scan(
            body, x, (gp, cache[f"g{gi}"], carry[f"g{gi}"]))
        new_cache[f"g{gi}"] = cg
        new_carry[f"g{gi}"] = carg

    x = apply_norm(params["final_norm"], x[:, -1:], cfg)
    return lm_logits(params, x, cfg)[:, 0], new_cache, new_carry


# ---------------------------------------------------------------------------
# Prefill (forward + cache construction)
# ---------------------------------------------------------------------------

def _fill_kv(cfg: ModelConfig, kind: str, k: jnp.ndarray, v: jnp.ndarray,
             cache_len: int) -> dict:
    """Place full-sequence K/V (B,S,KV,hd) into a ring cache of length L,
    consistent with the decode-side slot = pos % L convention."""
    B, S, KV, hd = k.shape
    L = cfg.kv_cache_len(kind, cache_len)
    Lp = min(L, S)
    pos = S - Lp + jnp.arange(Lp)
    slots = jnp.mod(pos, L)
    buf_k = jnp.zeros((B, L, KV, hd), k.dtype).at[:, slots].set(k[:, S - Lp:])
    buf_v = jnp.zeros((B, L, KV, hd), v.dtype).at[:, slots].set(v[:, S - Lp:])
    return {"k": buf_k, "v": buf_v}


def _prefill_block(bp: dict, kind: str, x: jnp.ndarray, cfg: ModelConfig,
                   ctx: Ctx, cache_len: int) -> tuple[jnp.ndarray, dict]:
    h = apply_norm(bp["ln1"], x, cfg)
    c: dict = {}
    if kind in ("attn", "local", "swa"):
        q, kk, vv = attn._qkv(bp["mix"], h, cfg)
        theta = attn._rope_theta(cfg, kind)
        from .layers import apply_rope
        q = apply_rope(q, ctx.positions, theta)
        kk = apply_rope(kk, ctx.positions, theta)
        window = cfg.window if kind in ("local", "swa") else 0
        from ..kernels import ops as kops
        o = kops.attention(q, kk, vv, causal=True, window=window)
        mix = o.reshape(o.shape[:-2] + (-1,)) @ bp["mix"]["wo"].astype(x.dtype)
        c = _fill_kv(cfg, kind, kk, vv, cache_len)
    elif kind == "xattn":
        mix = attn.cross_attention_fwd(bp["mix"], h, ctx.media, cfg)
    elif kind == "rwkv6":
        r, kk, vv, g, w = rwkv_mod._time_mix_inputs(bp["mix"], h, cfg, None)
        B, T, H, hd = r.shape
        from ..kernels import ops as kops
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        y, S1 = kops.rwkv6_stateful(r, kk, vv, w, bp["mix"]["bonus_u"], S0)
        mix = rwkv_mod._finish(bp["mix"], y, g, x.dtype, cfg)
        c = {"S": S1, "x_last": h[:, -1]}
    elif kind == "rglru":
        dt = x.dtype
        p = bp["mix"]
        y = jax.nn.gelu(h @ p["wy"].astype(dt), approximate=True)
        u_in = h @ p["wx"].astype(dt)
        u = rglru_mod._causal_conv(u_in, p["conv_w"], p["conv_b"], None)
        a, i = rglru_mod._gates(p, u)
        from ..kernels import ref as kref
        hseq, hT = kref.rglru(i * u, a)
        mix = (y * hseq) @ p["wo"].astype(dt)
        cw = cfg.conv_width
        tail = u_in[:, -(cw - 1):]
        pad = (cw - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        c = {"h": hT, "conv": tail}
    x = x + mix
    h2 = apply_norm(bp["ln2"], x, cfg)
    if kind == "rwkv6":
        f = rwkv_mod.chan_mix_fwd(bp["ffn"], h2, cfg)
        c["cx_last"] = h2[:, -1]
    else:
        f, _ = _apply_ffn(bp, kind, h2, cfg)
    return x + f, c


def prefill(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
            cache_len: int | None = None,
            media: jnp.ndarray | None = None,
            remat: str = "none",
            act_specs: dict | None = None) -> tuple[jnp.ndarray, dict]:
    """Process a prompt, returning (last-position logits (B, V), cache)."""
    B, S = tokens.shape
    cache_len = cache_len or S
    x = embed_tokens(params, tokens, cfg)
    x = _wsc(x, act_specs, "act")
    ctx = Ctx(positions=jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
    if cfg.frontend == "vision":
        ctx.media = media.astype(cfg.dtype) @ \
            params["frontend_proj"].astype(cfg.dtype)

    cache: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        gp = params["groups"][f"g{gi}"]

        def body(xc, slot_params, _g=g):
            xc = _wsc(xc, act_specs, "act")
            new_slots = {}
            for si, kind in enumerate(_g.pattern):
                xc, nc = _prefill_block(slot_params[f"s{si}"], kind, xc, cfg,
                                        ctx, cache_len)
                new_slots[f"s{si}"] = nc
            return xc, new_slots

        x, cache_g = jax.lax.scan(_remat_wrap(body, remat), x, gp)
        cache[f"g{gi}"] = cache_g

    x = apply_norm(params["final_norm"], x[:, -1:], cfg)
    return lm_logits(params, x, cfg)[:, 0], cache
