"""Griffin recurrent block: causal conv1d + RG-LRU gated linear recurrence.
arXiv:2402.19427 (RecurrentGemma uses this block 2:1 with local attention).

    branch_y = GeLU(x W_y)
    u        = x W_x ; u = CausalConv1d(u, width)
    a_t      = exp(-c * softplus(Lambda) * sigmoid(u W_a + b_a))
    i_t      = sigmoid(u W_i + b_i)
    h_t      = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * u_t)
    out      = (branch_y * h) W_o
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .paramlib import P
from ..kernels import ops as kops

_C = 8.0  # Griffin's fixed decay sharpness constant


def rglru_specs(cfg: ModelConfig, stack: tuple[int, ...] = ()) -> dict:
    lead = ("layers",) * len(stack)
    d, dr, cw = cfg.d_model, cfg.rnn_width, cfg.conv_width
    return {
        "wy": P(stack + (d, dr), lead + ("embed", "ffn")),
        "wx": P(stack + (d, dr), lead + ("embed", "ffn")),
        "conv_w": P(stack + (cw, dr), lead + (None, "ffn"), scale=0.1),
        "conv_b": P(stack + (dr,), lead + ("ffn",), init="zeros"),
        "wa": P(stack + (dr, dr), lead + ("ffn", "ffn2"), scale=0.01),
        "ba": P(stack + (dr,), lead + ("ffn",), init="zeros"),
        "wi": P(stack + (dr, dr), lead + ("ffn", "ffn2"), scale=0.01),
        "bi": P(stack + (dr,), lead + ("ffn",), init="zeros"),
        "lam": P(stack + (dr,), lead + ("ffn",), scale=0.5),
        "wo": P(stack + (dr, d), lead + ("ffn", "embed")),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 carry: jnp.ndarray | None) -> jnp.ndarray:
    """Depthwise causal conv over time.  u: (B, T, dr); w: (cw, dr);
    carry: (B, cw-1, dr) previous inputs (decode) or None (zeros)."""
    cw = w.shape[0]
    if carry is None:
        up = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([carry.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(cw):
        out = out + up[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
    return out + b.astype(u.dtype)


def _gates(p: dict, u: jnp.ndarray):
    uf = u.astype(jnp.float32)
    ra = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32)
                        + p["ba"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * ra
    a = jnp.exp(log_a)
    i = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32)
                       + p["bi"].astype(jnp.float32))
    return a.astype(u.dtype), i.astype(u.dtype)


def rglru_fwd(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = x.dtype
    y = jax.nn.gelu(x @ p["wy"].astype(dt), approximate=True)
    u = _causal_conv(x @ p["wx"].astype(dt), p["conv_w"], p["conv_b"], None)
    a, i = _gates(p, u)
    h = kops.rglru(i * u, a)
    return (y * h) @ p["wo"].astype(dt)


def rglru_decode(p: dict, x: jnp.ndarray, state: dict,
                 cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """x: (B, T, d) (T=1 decode, T>1 prefill chunk); state: {'h': (B, dr)
    f32, 'conv': (B, cw-1, dr)}."""
    dt = x.dtype
    y = jax.nn.gelu(x @ p["wy"].astype(dt), approximate=True)
    u_in = x @ p["wx"].astype(dt)
    u = _causal_conv(u_in, p["conv_w"], p["conv_b"], state["conv"])
    a, i = _gates(p, u)
    h_seq, hT = kops.rglru_stateful(i * u, a, state["h"])
    out = (y * h_seq) @ p["wo"].astype(dt)
    # conv carry = last cw-1 inputs across carry+chunk (T may exceed 1)
    new_conv = jnp.concatenate([state["conv"],
                                u_in.astype(state["conv"].dtype)],
                               axis=1)[:, -(state["conv"].shape[1]):]
    return out, {"h": hT, "conv": new_conv}


def init_rglru_state(cfg: ModelConfig, batch: int,
                     stack: tuple[int, ...] = (), abstract: bool = False):
    dr, cw = cfg.rnn_width, cfg.conv_width
    shapes = {"h": (stack + (batch, dr), jnp.float32),
              "conv": (stack + (batch, cw - 1, dr), cfg.dtype)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, t) for k, (s, t) in shapes.items()}
    return {k: jnp.zeros(s, t) for k, (s, t) in shapes.items()}


def rglru_state_axes(stack_dims: int = 0):
    lead = ("layers",) * stack_dims
    return {"h": lead + ("batch", None), "conv": lead + ("batch", None, None)}
