"""Unified Op-history recording and staleness telemetry.

Every ParameterDB backend funnels its completed operations through one
:class:`Telemetry` object, so

  * ``history`` is the same :class:`repro.core.history.Op` sequence for the
    threaded runtime, the in-process replay backend and the JAX ring-buffer
    engine — ``history.is_sequentially_correct`` is the single semantic
    oracle for every execution mode;
  * staleness is measured uniformly: a read of chunk ``j`` at iteration
    ``alpha`` that observed version ``v`` has staleness ``(alpha - 1) - v``
    (0 under exact RC/WC; positive when reading stale values; negative when
    a racy policy such as SSP or Hogwild read *ahead* of the sequential
    schedule);
  * the fault-handling layer (``repro.runtime.fault``) reports retries and
    skipped steps into the same object, so one summary describes a run.

Thread-safe: the threaded backend calls in under its store lock, but the
fault layer may report from a different thread, so mutation is locked here
too.
"""
from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass
class StalenessStats:
    reads: int = 0
    writes: int = 0
    observed_reads: int = 0       # reads that reported a version
    stale_reads: int = 0          # reads with staleness > 0
    ahead_reads: int = 0          # reads with staleness < 0 (racy policies)
    max_staleness: float = float("-inf")   # over observed reads only
    min_staleness: float = float("inf")
    sum_staleness: float = 0.0
    retried_steps: int = 0
    skipped_steps: int = 0

    @property
    def mean_staleness(self) -> float:
        return (self.sum_staleness / self.observed_reads
                if self.observed_reads else 0.0)


class Telemetry:
    """Op history (optional) + staleness counters shared by all backends."""

    def __init__(self, record_history: bool = False):
        self._lock = threading.Lock()
        self.history: list | None = [] if record_history else None
        self.stats = StalenessStats()

    def on_read(self, worker: int, chunk: int, itr: int,
                version: int | None = None) -> None:
        from ..core.history import Op, READ
        with self._lock:
            s = self.stats
            s.reads += 1
            if version is not None:
                s.observed_reads += 1
                staleness = (itr - 1) - version
                s.sum_staleness += staleness
                s.max_staleness = max(s.max_staleness, staleness)
                s.min_staleness = min(s.min_staleness, staleness)
                if staleness > 0:
                    s.stale_reads += 1
                elif staleness < 0:
                    s.ahead_reads += 1
            if self.history is not None:
                self.history.append(Op(READ, worker, chunk, itr))

    def on_write(self, worker: int, chunk: int, itr: int) -> None:
        from ..core.history import Op, WRITE
        with self._lock:
            self.stats.writes += 1
            if self.history is not None:
                self.history.append(Op(WRITE, worker, chunk, itr))

    def on_retry(self, step: int) -> None:
        with self._lock:
            self.stats.retried_steps += 1

    def on_skip(self, step: int) -> None:
        with self._lock:
            self.stats.skipped_steps += 1

    def summary(self) -> dict:
        s = self.stats
        seen = s.observed_reads > 0
        return {
            "reads": s.reads, "writes": s.writes,
            "stale_reads": s.stale_reads, "ahead_reads": s.ahead_reads,
            "max_staleness": s.max_staleness if seen else 0.0,
            "min_staleness": s.min_staleness if seen else 0.0,
            "mean_staleness": s.mean_staleness,
            "retried_steps": s.retried_steps,
            "skipped_steps": s.skipped_steps,
        }
