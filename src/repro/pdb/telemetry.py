"""Unified Op-history recording and staleness telemetry.

Every ParameterDB backend funnels its completed operations through one
:class:`Telemetry` object, so

  * ``history`` is the same :class:`repro.core.history.Op` sequence for the
    threaded runtime, the in-process replay backend and the JAX ring-buffer
    engine — ``history.is_sequentially_correct`` is the single semantic
    oracle for every execution mode;
  * staleness is measured uniformly: a read of chunk ``j`` at iteration
    ``alpha`` that observed version ``v`` has staleness ``(alpha - 1) - v``
    (0 under exact RC/WC; positive when reading stale values; negative when
    a racy policy such as SSP or Hogwild read *ahead* of the sequential
    schedule);
  * the fault-handling layer (``repro.runtime.fault``) reports retries and
    skipped steps into the same object, so one summary describes a run.

Thread-safe: the threaded backend calls in under its store lock, but the
fault layer may report from a different thread, so mutation is locked here
too.

Distributed runs produce *one Telemetry per shard*.  Each shard stamps its
ops with a Lamport clock (monotone per shard, merged across processes via
the RPC layer), and :func:`merge_timed_histories` reassembles the global Op
history by a causality-consistent total order — per-shard order is
preserved, so per-chunk projections (what
``repro.core.history.is_sequentially_correct`` inspects) are exactly the
shard-local orders.  :func:`merge_stats` folds the per-shard staleness
counters into one :class:`StalenessStats`.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
from typing import Iterable, Sequence


@dataclasses.dataclass
class StalenessStats:
    reads: int = 0
    writes: int = 0
    observed_reads: int = 0       # reads that reported a version
    stale_reads: int = 0          # reads with staleness > 0
    ahead_reads: int = 0          # reads with staleness < 0 (racy policies)
    max_staleness: float = float("-inf")   # over observed reads only
    min_staleness: float = float("inf")
    sum_staleness: float = 0.0
    retried_steps: int = 0
    skipped_steps: int = 0

    @property
    def mean_staleness(self) -> float:
        return (self.sum_staleness / self.observed_reads
                if self.observed_reads else 0.0)


class Telemetry:
    """Op history (optional) + staleness counters shared by all backends."""

    def __init__(self, record_history: bool = False):
        self._lock = threading.Lock()
        self.history: list | None = [] if record_history else None
        self.lamports: list[int] | None = [] if record_history else None
        self.stats = StalenessStats()

    # Telemetry objects cross process boundaries in the sharded backend
    # (snapshot/restore, PULL responses); locks don't pickle.
    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def on_read(self, worker: int, chunk: int, itr: int,
                version: int | None = None,
                lamport: int | None = None) -> None:
        from ..core.history import Op, READ
        with self._lock:
            s = self.stats
            s.reads += 1
            if version is not None:
                s.observed_reads += 1
                staleness = (itr - 1) - version
                s.sum_staleness += staleness
                s.max_staleness = max(s.max_staleness, staleness)
                s.min_staleness = min(s.min_staleness, staleness)
                if staleness > 0:
                    s.stale_reads += 1
                elif staleness < 0:
                    s.ahead_reads += 1
            if self.history is not None:
                self.history.append(Op(READ, worker, chunk, itr))
                self.lamports.append(lamport if lamport is not None
                                     else len(self.lamports))

    def on_write(self, worker: int, chunk: int, itr: int,
                 lamport: int | None = None) -> None:
        from ..core.history import Op, WRITE
        with self._lock:
            self.stats.writes += 1
            if self.history is not None:
                self.history.append(Op(WRITE, worker, chunk, itr))
                self.lamports.append(lamport if lamport is not None
                                     else len(self.lamports))

    def timed_history(self) -> list[tuple[int, object]]:
        """``[(lamport, Op), ...]`` in recording order (for merging)."""
        if self.history is None:
            return []
        with self._lock:
            return list(zip(self.lamports, self.history))

    def on_retry(self, step: int) -> None:
        with self._lock:
            self.stats.retried_steps += 1

    def on_skip(self, step: int) -> None:
        with self._lock:
            self.stats.skipped_steps += 1

    def summary(self) -> dict:
        return summarize(self.stats)


def summarize(s: StalenessStats) -> dict:
    seen = s.observed_reads > 0
    return {
        "reads": s.reads, "writes": s.writes,
        "stale_reads": s.stale_reads, "ahead_reads": s.ahead_reads,
        "max_staleness": s.max_staleness if seen else 0.0,
        "min_staleness": s.min_staleness if seen else 0.0,
        "mean_staleness": s.mean_staleness,
        "retried_steps": s.retried_steps,
        "skipped_steps": s.skipped_steps,
    }


# ---------------------------------------------------------------------------
# Cross-shard merging (the distributed backend's telemetry reassembly)
# ---------------------------------------------------------------------------

def merge_timed_histories(
        parts: Sequence[Sequence[tuple[int, object]]]) -> list:
    """Reassemble one global Op history from per-shard Lamport-stamped
    histories.

    Ops are totally ordered by ``(lamport, shard_index, arrival_index)``.
    Lamport stamps are strictly increasing within a shard, so the merge
    preserves every shard's local order — and since each chunk is owned by
    exactly one shard, every per-chunk projection of the merged history
    equals its shard-local projection.  That makes the merge *sound* for
    ``repro.core.history.is_sequentially_correct``, whose conditions are
    per-chunk; the Lamport order additionally respects cross-shard
    causality carried by the RPC clock exchange.
    """
    streams = [
        [(ts, shard_idx, seq, op) for seq, (ts, op) in enumerate(part)]
        for shard_idx, part in enumerate(parts)
    ]
    return [op for _, _, _, op in heapq.merge(*streams)]


def merge_stats(parts: Iterable[StalenessStats]) -> StalenessStats:
    """Fold per-shard staleness counters into one global StalenessStats."""
    out = StalenessStats()
    for s in parts:
        out.reads += s.reads
        out.writes += s.writes
        out.observed_reads += s.observed_reads
        out.stale_reads += s.stale_reads
        out.ahead_reads += s.ahead_reads
        out.max_staleness = max(out.max_staleness, s.max_staleness)
        out.min_staleness = min(out.min_staleness, s.min_staleness)
        out.sum_staleness += s.sum_staleness
        out.retried_steps += s.retried_steps
        out.skipped_steps += s.skipped_steps
    return out
