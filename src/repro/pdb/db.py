"""The ParameterDB: one consistency layer, many execution backends.

A :class:`ParameterDB` holds the chunked parameter vector and admits
``read(worker, chunk, itr)`` / ``write(worker, chunk, itr, value)`` under a
pluggable consistency :mod:`policy <repro.pdb.policies>`.  The *only*
difference between backends is what happens when an operation is not yet
admissible:

  * :class:`InProcessParameterDB` raises :class:`InadmissibleOp` — callers
    (the interleaved replay driver below, conformance tests, simulators)
    choose their own op order and must only issue admissible ops;
  * :class:`ThreadedParameterDB` blocks the calling thread on one shared
    condition variable until the policy admits the op — the single
    wait-condition implementation behind what used to be three divergent
    stores (``RCWCStore``, ``BSPStore``, and the ad-hoc launch path).

Both record the identical Op history and staleness telemetry through
:class:`repro.pdb.telemetry.Telemetry`, so
``repro.core.history.is_sequentially_correct`` applies to every backend.
"""
from __future__ import annotations

import threading
from typing import Callable, Sequence

import numpy as np

from .policies import Policy, make_policy
from .telemetry import Telemetry


class InadmissibleOp(RuntimeError):
    """A non-blocking backend was asked to execute an op its policy rejects."""


class WaitTimeout(RuntimeError):
    """A blocking backend gave up waiting for an op to become admissible.

    Carries the stalled op's coordinates so drivers and tests can tell
    *which* operation deadlocked, not just that something did.  Raised by
    :class:`ThreadedParameterDB` and by the RPC timeout path of the
    distributed client (:mod:`repro.pdb.server.client`) with an identical
    diagnostic."""

    def __init__(self, kind: str, worker: int, chunk: int, itr: int,
                 timeout: float | None, policy: Policy, where: str = "",
                 message: str | None = None):
        self.kind, self.worker, self.chunk, self.itr = kind, worker, chunk, itr
        self.timeout = timeout
        super().__init__(message if message is not None else
                         stall_diagnostic(kind, worker, chunk, itr,
                                          timeout, policy, where))


def stall_diagnostic(kind: str, worker: int, chunk: int, itr: int,
                     timeout: float | None, policy: Policy,
                     where: str = "") -> str:
    """One formatted line naming the stalled op and the policy state that is
    blocking it — shared by the threaded backend's condition-variable wait
    and the distributed client's RPC timeout."""
    op = f"{kind}{worker}[pi{chunk}][{itr}]"
    state = ""
    describe = getattr(policy, "describe", None)
    if describe is not None:
        try:
            state = f"; state: {describe(worker, chunk, itr)}"
        except Exception:
            state = ""
    suffix = f" at {where}" if where else ""
    return (f"ParameterDB wait timed out after {timeout}s on {op}{suffix} "
            f"(worker={worker} chunk={chunk} itr={itr}, "
            f"policy={type(policy).__name__}{state})")


class ParameterDB:
    """Shared storage + admission + telemetry; subclasses define waiting."""

    def __init__(self, init_chunks: Sequence[np.ndarray], n_workers: int,
                 policy: Policy | str = "dc",
                 delta: float | Sequence[float] = 0,
                 record: bool = False):
        self.chunks = [np.array(c, copy=True) for c in init_chunks]
        self.p = n_workers
        self.m = len(self.chunks)
        if isinstance(policy, str):
            policy = make_policy(policy, n_workers, delta, n_chunks=self.m)
        self.policy = policy
        # last committed iteration per chunk, for staleness telemetry
        # (kept here, not in the policy: SSP has no chunk versions)
        self._version = [0] * self.m
        self.telemetry = Telemetry(record_history=record)

    # -- admission passthroughs (for drivers that pick their own op order) --
    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.policy.can_read(worker, chunk, itr)

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return self.policy.can_write(worker, chunk, itr)

    @property
    def history(self):
        return self.telemetry.history

    def read_all(self, worker: int, itr: int) -> list[np.ndarray]:
        """The full Def-3 read set of one iteration — a first-class backend
        method, not a convenience loop: backends where a read crosses a
        process boundary override it with a batched multi-chunk request
        (``repro.pdb.server.client`` coalesces it into one ``read_batch``
        RPC per shard).  The default issues per-chunk reads in admission
        order, which every in-process backend executes exactly."""
        return [self.read(worker, j, itr) for j in range(self.m)]

    def values(self) -> list[np.ndarray]:
        return [c.copy() for c in self.chunks]

    def theta(self) -> np.ndarray:
        return np.concatenate(self.chunks)

    # -- the commit bodies shared by every subclass (call under exclusion) --
    def _do_read(self, worker: int, chunk: int, itr: int) -> np.ndarray:
        val = self.chunks[chunk].copy()
        self.policy.did_read(worker, chunk, itr)
        self.telemetry.on_read(worker, chunk, itr, self._version[chunk])
        return val

    def _do_write(self, worker: int, chunk: int, itr: int,
                  value: np.ndarray) -> None:
        self.chunks[chunk] = np.asarray(value)
        self._version[chunk] = max(self._version[chunk], itr)
        self.policy.did_write(worker, chunk, itr)
        self.telemetry.on_write(worker, chunk, itr)


class InProcessParameterDB(ParameterDB):
    """Non-blocking numpy backend: inadmissible ops raise."""

    def read(self, worker: int, chunk: int, itr: int) -> np.ndarray:
        if not self.policy.can_read(worker, chunk, itr):
            raise InadmissibleOp(f"r{worker}[pi{chunk}][{itr}]")
        return self._do_read(worker, chunk, itr)

    def write(self, worker: int, chunk: int, itr: int,
              value: np.ndarray) -> None:
        if not self.policy.can_write(worker, chunk, itr):
            raise InadmissibleOp(f"w{worker}[pi{chunk}][{itr}]")
        self._do_write(worker, chunk, itr, value)


class ThreadedParameterDB(ParameterDB):
    """Blocking backend: one condition variable, admission by the policy.

    read  blocks until policy.can_read(worker, chunk, itr)
    write blocks until policy.can_write(worker, chunk, itr)

    This subsumes both Algorithm 2a (BSP barriers) and Algorithm 2b / the
    Sec-7.1 protocol: the barrier-vs-constraint distinction lives entirely
    in the policy's admission predicates.
    """

    def __init__(self, *args, timeout: float | None = 300.0, **kwargs):
        super().__init__(*args, **kwargs)
        self.cond = threading.Condition()
        self.timeout = timeout

    def _wait_for(self, pred: Callable[[], bool], kind: str,
                  worker: int, chunk: int, itr: int) -> None:
        if not self.cond.wait_for(pred, timeout=self.timeout):
            raise WaitTimeout(kind, worker, chunk, itr, self.timeout,
                              self.policy)

    def read(self, worker: int, chunk: int, itr: int) -> np.ndarray:
        with self.cond:
            self._wait_for(
                lambda: self.policy.can_read(worker, chunk, itr),
                "r", worker, chunk, itr)
            val = self._do_read(worker, chunk, itr)
            self.cond.notify_all()
            return val

    def write(self, worker: int, chunk: int, itr: int,
              value: np.ndarray) -> None:
        with self.cond:
            self._wait_for(
                lambda: self.policy.can_write(worker, chunk, itr),
                "w", worker, chunk, itr)
            self._do_write(worker, chunk, itr, value)
            self.cond.notify_all()


# ---------------------------------------------------------------------------
# Deterministic interleaved driver (in-process backend)
# ---------------------------------------------------------------------------

UpdateFn = Callable[[int, np.ndarray, int], np.ndarray]
# update(worker, full_theta_snapshot, itr) -> new value for worker's chunk


def run_interleaved(db: InProcessParameterDB, n_iters: int,
                    update: UpdateFn, seed: int = 0) -> np.ndarray:
    """Drive every worker's Def-3 program (read all chunks, compute, write
    own chunk) through ``db``, choosing uniformly at random among the
    admissible next ops — a seeded single-threaded model of an arbitrary
    parallel interleaving.  Deterministic given ``seed``; raises if the
    policy ever deadlocks.  Returns the final concatenated theta."""
    import random as _random

    rng = _random.Random(seed)
    p, m = db.p, db.m
    itr = [1] * p
    unread = [set(range(m)) for _ in range(p)]
    buffers: list[dict[int, np.ndarray]] = [{} for _ in range(p)]

    while any(a <= n_iters for a in itr):
        moves: list[tuple[str, int, int]] = []
        for i in range(p):
            if itr[i] > n_iters:
                continue
            if unread[i]:
                moves += [("r", i, j) for j in sorted(unread[i])
                          if db.can_read(i, j, itr[i])]
            elif db.can_write(i, i, itr[i]):
                moves.append(("w", i, i))
        if not moves:
            raise RuntimeError(
                f"deadlock in run_interleaved "
                f"(policy={type(db.policy).__name__})")
        kind, i, j = rng.choice(moves)
        if kind == "r":
            buffers[i][j] = db.read(i, j, itr[i])
            unread[i].discard(j)
        else:
            snap = np.concatenate([buffers[i][k] for k in range(m)])
            db.write(i, i, itr[i], update(i, snap, itr[i]))
            itr[i] += 1
            unread[i] = set(range(m))
            buffers[i] = {}
    return db.theta()
