"""JAX device backend of the ParameterDB: the delta-staleness ring buffer.

On SPMD hardware there is no intra-program asynchrony, so the paper's
admissible-delay semantics is mapped onto *steps*: the gradient at step
``alpha`` is evaluated at the parameters of step ``alpha - delta`` and
applied to the parameters of step ``alpha``.  A ring buffer holds the last
``delta + 1`` parameter versions; per-partition-group delays (the Sec-7.1
per-chunk version arrays) let different parts of the model read different
staleness levels.

``delta = 0`` is bit-identical to synchronous training (asserted in
tests/test_staleness_jax.py and the pdb conformance suite) — the Sec-4
sequential-correctness guarantee.  ``delta = inf`` has no finite buffer;
the engine caps at the configured delta, which is the bounded-staleness
regime of SSP/parameter-server work the paper positions itself against.

:class:`TrainEngine` wraps both the plain synchronous path (delta=0, no
ring-buffer overhead) and the delayed path behind one step interface, with
the same Op-history / staleness telemetry as the other backends: each
training step is the single logical SPMD worker executing its Def-3 program
over the partition groups (read every group at its configured delay, write
every group), validated against a :class:`repro.pdb.policies.DeltaPolicy`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .telemetry import Telemetry

PyTree = Any


@dataclasses.dataclass
class DelayedState:
    params: PyTree          # current theta[alpha]
    hist: PyTree            # stacked (delta+1, ...) ring buffer of versions
    ptr: jnp.ndarray        # ring position of theta[alpha]
    opt_state: PyTree
    step: jnp.ndarray

    def tree_flatten(self):
        return ((self.params, self.hist, self.ptr, self.opt_state, self.step),
                None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    DelayedState,
    lambda s: s.tree_flatten(),
    lambda aux, ch: DelayedState.tree_unflatten(aux, ch))


# ---------------------------------------------------------------------------
# Packed ring layout (the Pallas fast path)
#
# Leaves are grouped by (admissible delay, dtype), flattened and concatenated
# into one (size, N) buffer per group, N padded to the 128-lane tile.  A
# stale read is then ONE row-gather per group (kernels/ring_gather.py, row
# index via scalar prefetch) instead of one dynamic-slice DMA per leaf; a
# write is one row update per group.  Packing round-trips bit-exactly, so
# the delta=0 sequential-correctness guarantee is untouched (asserted in
# tests/test_staleness_jax.py).
# ---------------------------------------------------------------------------

_LANE = 128


class _PackGroup(NamedTuple):
    key: str                              # "d<delay>_<dtype>"
    delay: int
    dtype: Any
    idxs: tuple[int, ...]                 # flat-leaf indices in this group
    shapes: tuple[tuple[int, ...], ...]
    sizes: tuple[int, ...]
    pad: int                              # zero-pad to the lane tile


def _pack_plan(params: PyTree, delta: int,
               delay_for: Callable[[tuple], int] | None
               ) -> tuple[list[_PackGroup], Any, int]:
    leaves = jax.tree_util.tree_leaves_with_path(params)
    treedef = jax.tree_util.tree_structure(params)
    by_key: dict[tuple, list] = {}
    for i, (path, leaf) in enumerate(leaves):
        d = delta if delay_for is None else min(delay_for(path), delta)
        dt = jnp.asarray(leaf).dtype
        by_key.setdefault((d, dt.name), []).append((i, tuple(leaf.shape), dt))
    plan = []
    for (d, dtname) in sorted(by_key):
        members = by_key[(d, dtname)]
        sizes = tuple(int(np.prod(s)) for _, s, _ in members)
        plan.append(_PackGroup(
            key=f"d{d}_{dtname}", delay=d, dtype=members[0][2],
            idxs=tuple(m[0] for m in members),
            shapes=tuple(m[1] for m in members),
            sizes=sizes, pad=(-sum(sizes)) % _LANE))
    return plan, treedef, len(leaves)


def _pack_rows(plan: list[_PackGroup], leaves: list) -> dict:
    rows = {}
    for g in plan:
        parts = [jnp.ravel(leaves[i]).astype(g.dtype) for i in g.idxs]
        row = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        if g.pad:
            row = jnp.pad(row, (0, g.pad))
        rows[g.key] = row
    return rows


def _unpack_rows(plan: list[_PackGroup], rows: dict, treedef: Any,
                 n_leaves: int) -> PyTree:
    out: list = [None] * n_leaves
    for g in plan:
        row, off = rows[g.key], 0
        for i, shape, sz in zip(g.idxs, g.shapes, g.sizes):
            out[i] = jax.lax.slice_in_dim(row, off, off + sz).reshape(shape)
            off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


def _resolve_packed(packed: bool | None) -> bool:
    """Default layout follows the kernel dispatch: the Pallas impls
    (``REPRO_KERNEL_IMPL=pallas|interpret``) use the packed ring."""
    return kops.kernel_impl() != "ref" if packed is None else packed


def init_delayed_state(params: PyTree, opt_init: Callable[[PyTree], PyTree],
                       delta: int, packed: bool | None = None,
                       delay_for: Callable[[tuple], int] | None = None
                       ) -> DelayedState:
    """Ring buffer starts filled with theta[0] (the paper's convention that
    reads clipped below iteration 1 see the initial values).  ``packed``
    selects the grouped (size, N) layout (see module notes); it must match
    the ``make_delayed_step`` that consumes the state."""
    size = delta + 1
    if _resolve_packed(packed):
        plan, _, _ = _pack_plan(params, delta, delay_for)
        rows = _pack_rows(plan, jax.tree_util.tree_leaves(params))
        hist = {k: jnp.broadcast_to(r[None], (size,) + r.shape)
                for k, r in rows.items()}
    else:
        hist = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (size,) + x.shape), params)
    return DelayedState(params=params, hist=hist,
                        ptr=jnp.zeros((), jnp.int32),
                        opt_state=opt_init(params),
                        step=jnp.zeros((), jnp.int32))


def make_delayed_step(
    grad_fn: Callable[[PyTree, Any], tuple[jnp.ndarray, PyTree]],
    opt_update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]],
    delta: int,
    delay_for: Callable[[tuple], int] | None = None,
    packed: bool | None = None,
) -> Callable[[DelayedState, Any], tuple[DelayedState, dict]]:
    """Build a jit-able delayed-gradient step.

    grad_fn(params, batch) -> (loss, grads)
    opt_update(grads, opt_state, params) -> (new_params, new_opt_state)
    delay_for(path) -> per-leaf delay in [0, delta]; default: uniform delta.
    packed: use the grouped ring layout + fused gather (default: follows
        ``REPRO_KERNEL_IMPL``).  The returned step exposes its stale-read
        as ``step.read_stale`` (parity tests / benchmarks).
    """
    size = delta + 1
    use_packed = _resolve_packed(packed)
    plan_cache: dict = {}

    def _plan_for(params: PyTree):
        # static, derived once — one engine, one tree structure
        if "plan" not in plan_cache:
            plan_cache["plan"] = _pack_plan(params, delta, delay_for)
        return plan_cache["plan"]

    def read_stale(state: DelayedState) -> PyTree:
        if use_packed:
            # state.params mirrors the (unpacked) tree the plan needs
            plan, treedef, n_leaves = _plan_for(state.params)
            rows = {}
            for g in plan:
                idx = jnp.mod(state.ptr - g.delay, size)
                rows[g.key] = kops.ring_gather(state.hist[g.key], idx)
            return _unpack_rows(plan, rows, treedef, n_leaves)

        def pick(path, hist_leaf):
            d = delta if delay_for is None else min(delay_for(path), delta)
            idx = jnp.mod(state.ptr - d, size)
            return jax.lax.dynamic_index_in_dim(hist_leaf, idx, axis=0,
                                                keepdims=False)
        return jax.tree_util.tree_map_with_path(pick, state.hist)

    def step(state: DelayedState, batch: Any) -> tuple[DelayedState, dict]:
        stale_params = read_stale(state)
        loss, grads = grad_fn(stale_params, batch)
        new_params, new_opt = opt_update(grads, state.opt_state, state.params)
        new_ptr = jnp.mod(state.ptr + 1, size)
        if use_packed:
            plan, _, _ = plan_cache["plan"]
            new_rows = _pack_rows(plan, jax.tree_util.tree_leaves(new_params))
            new_hist = {
                g.key: jax.lax.dynamic_update_index_in_dim(
                    state.hist[g.key], new_rows[g.key], new_ptr, axis=0)
                for g in plan}
        else:
            new_hist = jax.tree.map(
                lambda h, p: jax.lax.dynamic_update_index_in_dim(
                    h, p.astype(h.dtype), new_ptr, axis=0),
                state.hist, new_params)
        new_state = DelayedState(params=new_params, hist=new_hist,
                                 ptr=new_ptr, opt_state=new_opt,
                                 step=state.step + 1)
        return new_state, {"loss": loss, "staleness": jnp.asarray(delta)}

    step.read_stale = read_stale
    return step


# ---------------------------------------------------------------------------
# Unified train engine (the one JAX entry point for launch/train.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainEngine:
    """One step interface over both JAX execution paths.

    ``step(state, batch)`` runs the jitted update and records the step's
    Def-3 ops (one logical worker, one chunk per partition group) into the
    shared telemetry.  The recorded version of each read mirrors the ring
    buffer's indexing (reads clipped below step 1 see the initial values),
    so warmup staleness ramps 0..delay exactly as on device.

    Drivers that may *discard* a step's result (e.g. the fault layer
    skipping a non-finite step) should call ``step_fn`` directly and then
    ``record_step()`` only for accepted steps, so the Op history matches
    the actual parameter evolution.
    """

    init_state: Callable[[], Any]
    step_fn: Callable[[Any, Any], tuple[Any, dict]]   # jitted
    telemetry: Telemetry
    delta: int
    group_delays: tuple[int, ...]                     # delay per chunk/group

    def __post_init__(self):
        self._itr = 0

    def step(self, state: Any, batch: Any) -> tuple[Any, dict]:
        new_state, metrics = self.step_fn(state, batch)
        self.record_step()
        return new_state, metrics

    def record_step(self) -> None:
        """Log one committed step's ops into the shared telemetry."""
        self._itr += 1
        itr = self._itr
        for g, d in enumerate(self.group_delays):
            self.telemetry.on_read(0, g, itr, version=max(itr - 1 - d, 0))
        for g in range(len(self.group_delays)):
            self.telemetry.on_write(0, g, itr)

    @property
    def history(self):
        return self.telemetry.history


def make_engine(params: PyTree,
                grad_fn: Callable[[PyTree, Any], tuple[jnp.ndarray, PyTree]],
                opt: Any, sync: Any,
                record_history: bool = False) -> TrainEngine:
    """Build the unified engine from a grad function and a SyncConfig-like
    object (``delta``, ``group_delays``, ``delay_for``).

    delta == 0 and no group delays: plain synchronous dict state
    {"params", "opt"} (checkpoint-compatible with the historical layout);
    otherwise: :class:`DelayedState` ring buffer with per-group delays.
    """
    delta = int(getattr(sync, "delta", 0))
    group_delays_cfg = tuple(getattr(sync, "group_delays", ()) or ())
    leaves = jax.tree_util.tree_leaves_with_path(params)
    if delta > 0 and group_delays_cfg:
        delay_fn = sync.delay_for
        delays = tuple(min(delay_fn(path), delta) for path, _ in leaves)
    else:
        delays = tuple(delta for _ in leaves)
    telemetry = Telemetry(record_history=record_history)

    if delta == 0:
        def sync_step(state, batch):
            loss, grads = grad_fn(state["params"], batch)
            new_params, new_opt = opt.update(grads, state["opt"],
                                             state["params"])
            return ({"params": new_params, "opt": new_opt},
                    {"loss": loss, "staleness": jnp.zeros((), jnp.int32)})

        return TrainEngine(
            init_state=lambda: {"params": params, "opt": opt.init(params)},
            step_fn=jax.jit(sync_step),
            telemetry=telemetry, delta=0, group_delays=delays)

    delay_for = sync.delay_for if group_delays_cfg else None
    packed = _resolve_packed(getattr(sync, "packed_ring", None))
    raw = make_delayed_step(grad_fn, opt.update, delta, delay_for,
                            packed=packed)
    return TrainEngine(
        init_state=lambda: init_delayed_state(params, opt.init, delta,
                                              packed=packed,
                                              delay_for=delay_for),
        step_fn=jax.jit(raw),
        telemetry=telemetry, delta=delta, group_delays=delays)
