"""The parameter database: one consistency layer, four backends.

This package is the repo's single implementation of the paper's
contribution — a parameter *database* whose read/write admission is decided
by a pluggable **consistency policy** and whose execution is provided by a
pluggable **backend**:

  policies     — BSP barriers (Alg 2a), Sec-5 RC/WC bit vector, Sec-7.1
                 delta admissible delay (uniform or per-chunk), SSP and
                 value-bounded staleness on first-class per-worker
                 vector clocks
  db           — in-process numpy backend (raises on inadmissible ops) and
                 blocking-threaded backend (one condition variable)
  server       — multi-process sharded backend: chunks hash-sharded over
                 TCP shard servers, worker-side ClientParameterDB with a
                 policy-bounded versioned cache and clock gossip
  jax_backend  — device ring buffer of the last delta+1 parameter versions
                 + the unified TrainEngine used by repro.launch.train
  telemetry    — shared Op-history recording and staleness statistics;
                 cross-shard history merge (merge_timed_histories)

Every backend emits the same :class:`repro.core.history.Op` history, so
``repro.core.history.is_sequentially_correct`` is the semantic oracle for
all execution modes; ``tests/test_pdb_conformance.py`` holds the
policy x backend conformance matrix.

The legacy entry points (``repro.core.scheduler``, ``repro.core.threaded``,
``repro.core.staleness``) are thin shims over this package.
"""
from .db import (InProcessParameterDB, InadmissibleOp, ParameterDB,  # noqa: F401
                 ThreadedParameterDB, WaitTimeout, run_interleaved,
                 stall_diagnostic)
from .policies import (POLICIES, BSPPolicy, BitVectorPolicy, DeltaPolicy,  # noqa: F401
                       Policy, SSPPolicy, ValueBoundPolicy, VectorClocks,
                       make_policy, random_schedule,
                       ssp_clock_bound_violations)
from .telemetry import (StalenessStats, Telemetry, merge_stats,  # noqa: F401
                        merge_timed_histories)

_JAX_EXPORTS = ("DelayedState", "TrainEngine", "init_delayed_state",
                "make_delayed_step", "make_engine")


def __getattr__(name):
    # the device backend pulls in jax; load it only when actually used so
    # the pure-python policies/backends stay importable without it
    if name in _JAX_EXPORTS:
        from . import jax_backend
        return getattr(jax_backend, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
