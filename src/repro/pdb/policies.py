"""Consistency policies of the parameter database (paper Secs 4-5, 7.1).

A *policy* is the pure-bookkeeping admission engine behind every execution
backend: ``can_read / can_write`` test whether a Def-3 operation is
admissible right now, ``did_read / did_write`` record its completion.
Policies never block and never hold values — backends (``repro.pdb.db``,
``repro.pdb.jax_backend``, the simulator) compose a policy with storage.

  * :class:`BitVectorPolicy` — the Sec-5 protocol verbatim: one bit per
    worker per chunk gates writes; a per-chunk iteration number gates reads.
    Enforces exact sequential semantics (delta = 0).
  * :class:`DeltaPolicy`     — the Sec-7.1 revised protocol: per-chunk
    last-read iteration arrays; admissible delay ``delta >= 0``, uniform or
    per-chunk.  ``delta=0`` coincides with :class:`BitVectorPolicy`;
    ``delta=inf`` degenerates to Hogwild!-style fully asynchronous execution.
  * :class:`BSPPolicy`       — the Algorithm-2a baseline: global read and
    write barriers expressed as admission predicates.
  * :class:`SSPPolicy`       — stale-synchronous-parallel (Petuum / Cipar et
    al.): per-worker clocks; a worker may start iteration ``alpha`` only if
    the slowest worker's clock is within ``slack``.  Writes are never gated,
    so SSP does *not* satisfy WC — it bounds divergence instead of
    eliminating it (the regime the paper positions itself against).
"""
from __future__ import annotations

import math
from typing import Protocol, Sequence


class Policy(Protocol):
    def can_read(self, worker: int, chunk: int, itr: int) -> bool: ...
    def can_write(self, worker: int, chunk: int, itr: int) -> bool: ...
    def did_read(self, worker: int, chunk: int, itr: int) -> None: ...
    def did_write(self, worker: int, chunk: int, itr: int) -> None: ...


class BitVectorPolicy:
    """Sec 5: 'a write on pi_i can be executed if this chunk has been read by
    all the worker processes in their alpha-th iterations' (bit vector), and
    'a read [at alpha+1] can be executed if [the chunk's] iteration number is
    one less than the iteration number in the read operation'."""

    name = "dc"
    sequential_at_zero = True

    def __init__(self, n_workers: int, n_chunks: int | None = None):
        self.p = n_workers
        self.m = n_chunks if n_chunks is not None else n_workers
        # start as if freshly written (version 0, bits zeroed): iteration-1
        # writes must wait for every worker's iteration-1 read of the chunk
        self.bits = [[False] * self.p for _ in range(self.m)]
        self.version = [0] * self.m  # iteration number of last executed write

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.version[chunk] == itr - 1

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.bits[chunk][worker] = True

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return all(self.bits[chunk])

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.bits[chunk] = [False] * self.p  # 'all bits are set to zero'
        self.version[chunk] = itr


class DeltaPolicy:
    """Sec 7.1: per-chunk last-read iteration array + chunk version.

    Read  r_i[pi_j][alpha] admissible iff version[j] >= alpha - 1 - delta_j.
    Write w_i[pi_i][alpha] admissible iff min_k last_read[i][k] >= alpha - delta_i.

    ``delta`` may be a scalar (uniform admissible delay) or a per-chunk
    sequence — the per-partition-group delays of Sec 7.1 (and of
    ``SyncConfig.group_delays`` on the JAX backend).
    """

    name = "dc-array"
    sequential_at_zero = True

    def __init__(self, n_workers: int, delta: float | Sequence[float] = 0,
                 n_chunks: int | None = None):
        self.p = n_workers
        if isinstance(delta, (int, float)):
            self.m = n_chunks if n_chunks is not None else n_workers
            deltas = [delta] * self.m
        else:
            deltas = list(delta)
            self.m = n_chunks if n_chunks is not None else len(deltas)
            if len(deltas) != self.m:
                raise ValueError("per-chunk delta length != n_chunks")
        if any(d < 0 for d in deltas):
            raise ValueError("delta must be >= 0")
        self.deltas = deltas
        self.version = [0] * self.m
        self.last_read = [[0] * self.p for _ in range(self.m)]

    @property
    def delta(self) -> float:
        """The uniform delay (max over chunks for heterogeneous configs)."""
        return max(self.deltas)

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.version[chunk] >= itr - 1 - self.deltas[chunk]

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.last_read[chunk][worker] = max(self.last_read[chunk][worker], itr)

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return min(self.last_read[chunk]) >= itr - self.deltas[chunk]

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.version[chunk] = max(self.version[chunk], itr)

    @property
    def hogwild(self) -> bool:
        return all(math.isinf(d) for d in self.deltas)


class BSPPolicy:
    """Algorithm 2a expressed as admission predicates.

    Read barrier:  no read of iteration alpha+1 until *every* worker's write
    of iteration alpha has executed.
    Write barrier: no write of iteration alpha until *every* worker has
    finished *all* its reads of iteration alpha.
    """

    name = "bsp"
    sequential_at_zero = True

    def __init__(self, n_workers: int, n_chunks: int | None = None):
        self.p = n_workers
        self.m = n_chunks if n_chunks is not None else n_workers
        self.writes_done = [0] * self.p      # writes_done[i] = last iter i wrote
        self.reads_done = [[0] * self.m for _ in range(self.p)]
        # reads_done[i][j] = last iter in which worker i read chunk j

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return all(v >= itr - 1 for v in self.writes_done)

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.reads_done[worker][chunk] = max(self.reads_done[worker][chunk], itr)

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return all(self.reads_done[i][j] >= itr
                   for i in range(self.p) for j in range(self.m))

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.writes_done[worker] = max(self.writes_done[worker], itr)


class SSPPolicy:
    """Stale synchronous parallel: per-worker clocks, bounded divergence.

    ``clock[i]`` is the last iteration worker ``i`` committed.  A read at
    iteration ``alpha`` is admissible iff ``min_k clock[k] >= alpha-1-slack``
    (the fastest worker is at most ``slack`` iterations ahead of the slowest);
    writes are never gated.  ``slack=0`` is BSP's read barrier *without* the
    write barrier — histories are clock-bounded but not sequentially correct,
    which is exactly the contrast the paper draws with RC/WC.
    """

    name = "ssp"
    sequential_at_zero = False

    def __init__(self, n_workers: int, slack: float = 0,
                 n_chunks: int | None = None):
        if slack < 0:
            raise ValueError("slack must be >= 0")
        self.p = n_workers
        self.m = n_chunks if n_chunks is not None else n_workers
        self.slack = slack
        self.clock = [0] * self.p

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return min(self.clock) >= itr - 1 - self.slack

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        pass

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return True

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.clock[worker] = max(self.clock[worker], itr)


POLICIES = ("bsp", "dc", "dc-array", "ssp", "hogwild")


def make_policy(policy: str, n_workers: int,
                delta: float | Sequence[float] = 0,
                n_chunks: int | None = None) -> Policy:
    """The single policy factory shared by every backend (threads, in-process
    replay, discrete-event simulator, JAX ring buffer)."""
    if policy == "bsp":
        return BSPPolicy(n_workers, n_chunks)
    if policy == "dc":
        if isinstance(delta, (int, float)) and delta == 0:
            return BitVectorPolicy(n_workers, n_chunks)
        return DeltaPolicy(n_workers, delta, n_chunks)
    if policy == "dc-array":  # Sec-7.1 engine even at delta=0
        return DeltaPolicy(n_workers, delta, n_chunks)
    if policy == "hogwild":
        return DeltaPolicy(n_workers, math.inf, n_chunks)
    if policy == "ssp":
        return SSPPolicy(n_workers, delta, n_chunks)
    raise ValueError(f"unknown policy {policy!r}")


def random_schedule(policy: str, n_workers: int, n_iters: int,
                    seed: int = 0, delta: float = 0) -> list:
    """Generate a random admissible execution history: at every step pick a
    uniformly random worker whose next Def-3 operation is admissible under
    the policy.  Used by the hypothesis property tests (every RC/WC history
    must be sequentially correct — Theorems 1/2), by the SSP clock-bound
    property test, and as a fuzzer for the admission engines (total progress
    = deadlock freedom).

    Implemented as the in-process ParameterDB backend driven with dummy
    values — one admissible-move driver (``run_interleaved``) serves both
    the fuzzer and the value-carrying conformance runs."""
    import numpy as np

    from .db import InProcessParameterDB, run_interleaved

    zero = np.zeros(1)
    db = InProcessParameterDB(
        [zero] * n_workers, n_workers,
        policy=make_policy(policy, n_workers, delta), record=True)
    run_interleaved(db, n_iters, lambda worker, snap, itr: zero, seed=seed)
    return db.history


def ssp_clock_bound_violations(history, n_workers: int, slack: float) -> list:
    """Replay a history against per-worker clocks and return every read that
    observed a clock gap larger than ``slack`` — empty iff the history
    respects the SSP bound."""
    from ..core.history import READ, WRITE

    clock = [0] * n_workers
    bad = []
    for op in history:
        if op.kind == READ:
            if (op.itr - 1) - min(clock) > slack:
                bad.append(op)
        elif op.kind == WRITE:
            clock[op.worker] = max(clock[op.worker], op.itr)
    return bad
