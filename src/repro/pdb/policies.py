"""Consistency policies of the parameter database (paper Secs 4-5, 7.1).

A *policy* is the pure-bookkeeping admission engine behind every execution
backend: ``can_read / can_write`` test whether a Def-3 operation is
admissible right now, ``did_read / did_write`` record its completion.
Policies never block and never hold values — backends (``repro.pdb.db``,
``repro.pdb.jax_backend``, the simulator, the multi-process
``repro.pdb.server`` shards) compose a policy with storage.

Every policy carries a first-class :class:`VectorClocks` — per-worker
``commit`` (last iteration whose write the worker committed) and
``frontier`` (last iteration whose *full read set* the worker completed).
The clock vectors are the state that must travel between processes in the
sharded parameter-server backend: chunk-local state (bit vectors, version
numbers, last-read arrays) stays at the shard that owns the chunk, while
clock-gated admission (BSP barriers, SSP slack) is evaluated against the
local clock vector, which is a *lower bound* of the true global clocks.
All admission predicates here are monotone in the clocks, so evaluating
them against a lower bound is safe — a remote shard or a caching client
can only be conservative, never admit an op the true state would reject.

  * :class:`BitVectorPolicy` — the Sec-5 protocol verbatim: one bit per
    worker per chunk gates writes; a per-chunk iteration number gates reads.
    Enforces exact sequential semantics (delta = 0).  Chunk-local.
  * :class:`DeltaPolicy`     — the Sec-7.1 revised protocol: per-chunk
    last-read iteration arrays; admissible delay ``delta >= 0``, uniform or
    per-chunk.  ``delta=0`` coincides with :class:`BitVectorPolicy`;
    ``delta=inf`` degenerates to Hogwild!-style fully asynchronous
    execution.  Chunk-local.
  * :class:`BSPPolicy`       — the Algorithm-2a baseline: global read and
    write barriers expressed over the clock vectors (``min commit`` gates
    reads, ``min frontier`` gates writes).
  * :class:`SSPPolicy`       — stale-synchronous-parallel (Petuum / Cipar /
    Ho et al.): per-worker commit clocks; a worker may read at iteration
    ``alpha`` only if the slowest worker's clock is within ``slack``.
    Writes are never gated, so SSP does *not* satisfy WC — it bounds
    divergence instead of eliminating it (the regime the paper positions
    itself against).
  * :class:`ValueBoundPolicy` — the value-bounded model of Dai et al.
    (2014): operations are never clock-gated (``delta=inf``), but a served
    value must be within ``vbound`` accumulated update magnitude of the
    freshest committed value.  The magnitude ledger lives with the storage
    (the server shard tracks per-chunk cumulative change), so
    ``cache_admissible`` is always False here: a cached value must be
    *validated* against the owner shard, which answers not-modified when
    the drift is within bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Protocol, Sequence


@dataclasses.dataclass
class VectorClocks:
    """Per-worker progress clocks: the only cross-shard policy state.

    ``commit[i]``   — last iteration whose write worker ``i`` committed.
    ``frontier[i]`` — last iteration for which worker ``i`` completed its
                      full Def-3 read set (all chunks read at that itr).

    Both vectors are monotone; ``observe_*`` merge remote knowledge by
    elementwise max, so any local copy is a lower bound of the truth.
    """

    commit: list[int]
    frontier: list[int]

    @classmethod
    def zero(cls, n_workers: int) -> "VectorClocks":
        return cls([0] * n_workers, [0] * n_workers)

    def observe_commit(self, worker: int, itr: int) -> None:
        self.commit[worker] = max(self.commit[worker], itr)

    def observe_frontier(self, worker: int, itr: int) -> None:
        self.frontier[worker] = max(self.frontier[worker], itr)

    def merge(self, commit: Sequence[int], frontier: Sequence[int]) -> None:
        for i, v in enumerate(commit):
            self.commit[i] = max(self.commit[i], v)
        for i, v in enumerate(frontier):
            self.frontier[i] = max(self.frontier[i], v)

    @property
    def min_commit(self) -> int:
        return min(self.commit)

    @property
    def min_frontier(self) -> int:
        return min(self.frontier)

    def as_dict(self) -> dict:
        return {"commit": list(self.commit), "frontier": list(self.frontier)}


class Policy(Protocol):
    def can_read(self, worker: int, chunk: int, itr: int) -> bool: ...
    def can_write(self, worker: int, chunk: int, itr: int) -> bool: ...
    def did_read(self, worker: int, chunk: int, itr: int) -> None: ...
    def did_write(self, worker: int, chunk: int, itr: int) -> None: ...


class BasePolicy:
    """Shared clock bookkeeping: every concrete policy owns VectorClocks."""

    name = "base"
    sequential_at_zero = False

    def __init__(self, n_workers: int, n_chunks: int | None = None):
        self.p = n_workers
        self.m = n_chunks if n_chunks is not None else n_workers
        self.clocks = VectorClocks.zero(n_workers)

    # -- remote clock observation (server shards, caching clients) ----------
    def observe_commit(self, worker: int, itr: int) -> None:
        self.clocks.observe_commit(worker, itr)

    def observe_frontier(self, worker: int, itr: int) -> None:
        self.clocks.observe_frontier(worker, itr)

    # -- client-side cache admissibility ------------------------------------
    def cache_admissible(self, chunk: int, cached_version: int,
                         itr: int) -> bool:
        """May a read ``r[chunk][itr]`` be served from a locally cached
        value at ``cached_version``, given only this instance's (lower
        bound) clock knowledge?  Default: never."""
        return False

    # -- stall diagnostics ---------------------------------------------------
    def describe(self, worker: int, chunk: int, itr: int) -> str:
        """Compact state relevant to the admission of one op, for timeout
        diagnostics."""
        c = self.clocks
        return f"commit={c.commit} frontier={c.frontier}"

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        pass

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.clocks.observe_commit(worker, itr)


class BitVectorPolicy(BasePolicy):
    """Sec 5: 'a write on pi_i can be executed if this chunk has been read by
    all the worker processes in their alpha-th iterations' (bit vector), and
    'a read [at alpha+1] can be executed if [the chunk's] iteration number is
    one less than the iteration number in the read operation'.

    All admission state is chunk-local, so the sharded server backend needs
    no cross-shard traffic to run this policy exactly."""

    name = "dc"
    sequential_at_zero = True

    def __init__(self, n_workers: int, n_chunks: int | None = None):
        super().__init__(n_workers, n_chunks)
        # start as if freshly written (version 0, bits zeroed): iteration-1
        # writes must wait for every worker's iteration-1 read of the chunk
        self.bits = [[False] * self.p for _ in range(self.m)]
        self.version = [0] * self.m  # iteration number of last executed write

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.version[chunk] == itr - 1

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.bits[chunk][worker] = True

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return all(self.bits[chunk])

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.bits[chunk] = [False] * self.p  # 'all bits are set to zero'
        self.version[chunk] = itr
        self.clocks.observe_commit(worker, itr)

    def cache_admissible(self, chunk: int, cached_version: int,
                         itr: int) -> bool:
        # exact: the chunk's version cannot pass itr-1 before *this* read
        # is recorded, so a cached itr-1 value is provably current
        return cached_version == itr - 1

    def describe(self, worker: int, chunk: int, itr: int) -> str:
        return (f"version[{chunk}]={self.version[chunk]} "
                f"bits[{chunk}]={self.bits[chunk]} "
                f"{super().describe(worker, chunk, itr)}")


class DeltaPolicy(BasePolicy):
    """Sec 7.1: per-chunk last-read iteration array + chunk version.

    Read  r_i[pi_j][alpha] admissible iff version[j] >= alpha - 1 - delta_j.
    Write w_i[pi_i][alpha] admissible iff min_k last_read[i][k] >= alpha - delta_i.

    ``delta`` may be a scalar (uniform admissible delay) or a per-chunk
    sequence — the per-partition-group delays of Sec 7.1 (and of
    ``SyncConfig.group_delays`` on the JAX backend).  Chunk-local.
    """

    name = "dc-array"
    sequential_at_zero = True

    def __init__(self, n_workers: int, delta: float | Sequence[float] = 0,
                 n_chunks: int | None = None):
        if isinstance(delta, (int, float)):
            m = n_chunks if n_chunks is not None else n_workers
            deltas = [delta] * m
        else:
            deltas = list(delta)
            m = n_chunks if n_chunks is not None else len(deltas)
            if len(deltas) != m:
                raise ValueError("per-chunk delta length != n_chunks")
        super().__init__(n_workers, m)
        if any(d < 0 for d in deltas):
            raise ValueError("delta must be >= 0")
        self.deltas = deltas
        self.version = [0] * self.m
        self.last_read = [[0] * self.p for _ in range(self.m)]

    @property
    def delta(self) -> float:
        """The uniform delay (max over chunks for heterogeneous configs)."""
        return max(self.deltas)

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.version[chunk] >= itr - 1 - self.deltas[chunk]

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.last_read[chunk][worker] = max(self.last_read[chunk][worker], itr)

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return min(self.last_read[chunk]) >= itr - self.deltas[chunk]

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.version[chunk] = max(self.version[chunk], itr)
        self.clocks.observe_commit(worker, itr)

    def cache_admissible(self, chunk: int, cached_version: int,
                         itr: int) -> bool:
        # the true version only advances, so a cached version satisfying the
        # bound stays admissible; infinite delay (hogwild) disables caching
        # entirely — an unsynchronized worker should keep observing fresh
        # values, not iterate on its first fetch forever
        d = self.deltas[chunk]
        return math.isfinite(d) and cached_version >= itr - 1 - d

    @property
    def hogwild(self) -> bool:
        return all(math.isinf(d) for d in self.deltas)

    def describe(self, worker: int, chunk: int, itr: int) -> str:
        return (f"version[{chunk}]={self.version[chunk]} "
                f"last_read[{chunk}]={self.last_read[chunk]} "
                f"delta[{chunk}]={self.deltas[chunk]} "
                f"{super().describe(worker, chunk, itr)}")


class BSPPolicy(BasePolicy):
    """Algorithm 2a expressed over the per-worker clock vectors.

    Read barrier:  no read of iteration alpha+1 until *every* worker's write
    of iteration alpha has executed — ``min commit >= alpha``.
    Write barrier: no write of iteration alpha until *every* worker has
    finished *all* its reads of iteration alpha — ``min frontier >= alpha``.

    The frontier clock advances locally when ``did_read`` completes a
    worker's read set; in the sharded backend (where one shard sees only
    its own chunks' reads) it advances via ``observe_frontier`` broadcasts
    instead.
    """

    name = "bsp"
    sequential_at_zero = True

    def __init__(self, n_workers: int, n_chunks: int | None = None):
        super().__init__(n_workers, n_chunks)
        self.reads_done = [[0] * self.m for _ in range(self.p)]
        # reads_done[i][j] = last iter in which worker i read chunk j

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.clocks.min_commit >= itr - 1

    def did_read(self, worker: int, chunk: int, itr: int) -> None:
        self.reads_done[worker][chunk] = max(self.reads_done[worker][chunk],
                                             itr)
        self.clocks.observe_frontier(worker, min(self.reads_done[worker]))

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return self.clocks.min_frontier >= itr

    def did_write(self, worker: int, chunk: int, itr: int) -> None:
        self.clocks.observe_commit(worker, itr)

    def cache_admissible(self, chunk: int, cached_version: int,
                         itr: int) -> bool:
        # under BSP every iteration-alpha read observes version alpha-1
        # exactly; min_commit is a lower bound, so this is conservative
        return cached_version == itr - 1 and self.clocks.min_commit >= itr - 1


class SSPPolicy(BasePolicy):
    """Stale synchronous parallel: per-worker commit clocks, bounded
    divergence.

    A read at iteration ``alpha`` is admissible iff
    ``min commit >= alpha - 1 - slack`` (the fastest worker is at most
    ``slack`` iterations ahead of the slowest); writes are never gated.
    ``slack=0`` is BSP's read barrier *without* the write barrier —
    histories are clock-bounded but not sequentially correct, which is
    exactly the contrast the paper draws with RC/WC.
    """

    name = "ssp"
    sequential_at_zero = False

    def __init__(self, n_workers: int, slack: float = 0,
                 n_chunks: int | None = None):
        if slack < 0:
            raise ValueError("slack must be >= 0")
        super().__init__(n_workers, n_chunks)
        self.slack = slack

    @property
    def clock(self) -> list[int]:
        """Back-compat alias: the per-worker commit clock vector."""
        return self.clocks.commit

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        return self.clocks.min_commit >= itr - 1 - self.slack

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        return True

    def cache_admissible(self, chunk: int, cached_version: int,
                         itr: int) -> bool:
        # serve a cached value only if it is itself within the clock bound
        # (exact clock-bounded staleness: the *served version*, not just the
        # op order, respects the slack), and the bound admits the read at all
        return (math.isfinite(self.slack)
                and cached_version >= itr - 1 - self.slack
                and self.clocks.min_commit >= itr - 1 - self.slack)

    def describe(self, worker: int, chunk: int, itr: int) -> str:
        return f"slack={self.slack} {super().describe(worker, chunk, itr)}"


class ValueBoundPolicy(DeltaPolicy):
    """Value-bounded staleness (Dai et al. 2014): clock-free admission with
    a bound on the *magnitude* of unseen updates.

    Ops are never gated (``delta=inf``); the guarantee is enforced where
    the values live: the owner shard keeps a per-chunk cumulative-update
    ledger (sum of L-inf write deltas) and serves a cached value only while
    its drift stays within ``vbound``.  ``cache_admissible`` is therefore
    always False — the client must *validate* with the shard, which answers
    not-modified (no payload) when the bound holds.
    """

    name = "vap"
    sequential_at_zero = False

    def __init__(self, n_workers: int, vbound: float = 0.0,
                 n_chunks: int | None = None):
        if vbound < 0:
            raise ValueError("vbound must be >= 0")
        super().__init__(n_workers, math.inf, n_chunks)
        self.vbound = vbound

    def cache_admissible(self, chunk: int, cached_version: int,
                         itr: int) -> bool:
        return False     # value bounds are checked against the ledger

    def describe(self, worker: int, chunk: int, itr: int) -> str:
        return f"vbound={self.vbound} {super().describe(worker, chunk, itr)}"


POLICIES = ("bsp", "dc", "dc-array", "ssp", "hogwild", "vap")


def make_policy(policy: str, n_workers: int,
                delta: float | Sequence[float] = 0,
                n_chunks: int | None = None,
                vbound: float | None = None) -> Policy:
    """The single policy factory shared by every backend (threads, in-process
    replay, discrete-event simulator, JAX ring buffer, server shards)."""
    if policy == "bsp":
        return BSPPolicy(n_workers, n_chunks)
    if policy == "dc":
        if isinstance(delta, (int, float)) and delta == 0:
            return BitVectorPolicy(n_workers, n_chunks)
        return DeltaPolicy(n_workers, delta, n_chunks)
    if policy == "dc-array":  # Sec-7.1 engine even at delta=0
        return DeltaPolicy(n_workers, delta, n_chunks)
    if policy == "hogwild":
        return DeltaPolicy(n_workers, math.inf, n_chunks)
    if policy == "ssp":
        return SSPPolicy(n_workers, delta, n_chunks)
    if policy == "vap":
        bound = vbound if vbound is not None else delta
        return ValueBoundPolicy(n_workers, bound, n_chunks)
    raise ValueError(f"unknown policy {policy!r}")


def random_schedule(policy: str, n_workers: int, n_iters: int,
                    seed: int = 0, delta: float = 0) -> list:
    """Generate a random admissible execution history: at every step pick a
    uniformly random worker whose next Def-3 operation is admissible under
    the policy.  Used by the hypothesis property tests (every RC/WC history
    must be sequentially correct — Theorems 1/2), by the SSP clock-bound
    property test, and as a fuzzer for the admission engines (total progress
    = deadlock freedom).

    Implemented as the in-process ParameterDB backend driven with dummy
    values — one admissible-move driver (``run_interleaved``) serves both
    the fuzzer and the value-carrying conformance runs."""
    import numpy as np

    from .db import InProcessParameterDB, run_interleaved

    zero = np.zeros(1)
    db = InProcessParameterDB(
        [zero] * n_workers, n_workers,
        policy=make_policy(policy, n_workers, delta), record=True)
    run_interleaved(db, n_iters, lambda worker, snap, itr: zero, seed=seed)
    return db.history


def ssp_clock_bound_violations(history, n_workers: int, slack: float) -> list:
    """Replay a history against per-worker clocks and return every read that
    observed a clock gap larger than ``slack`` — empty iff the history
    respects the SSP bound."""
    from ..core.history import READ, WRITE

    clock = [0] * n_workers
    bad = []
    for op in history:
        if op.kind == READ:
            if (op.itr - 1) - min(clock) > slack:
                bad.append(op)
        elif op.kind == WRITE:
            clock[op.worker] = max(clock[op.worker], op.itr)
    return bad
