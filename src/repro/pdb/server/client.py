"""Worker-side client of the sharded ParameterDB.

:class:`ClientParameterDB` exposes the exact interface of the in-process
backends — ``read / write / can_read / can_write / read_all`` plus
``history``-style telemetry — so the Sec-6 worker loop, the conformance
suite and the benchmarks run unchanged across process boundaries.

What the client adds over a dumb RPC stub:

  * a **versioned local cache**: a read is served locally when the cached
    version is admissible under the policy bound
    (``policy.cache_admissible``, a monotone predicate evaluated against
    the client's lower-bound clock knowledge — provably conservative).
    Cache-served reads still notify the owner shard (``notify_read``) so
    chunk-local admission state, the Op history and staleness telemetry
    stay authoritative at the shard; what a hit saves is the blocking
    admission wait and the value payload.  Inadmissible cached versions
    are *fetched-and-validated*: the shard answers not-modified (no
    payload) when the cached version is still current — or, under the
    value-bounded policy, when its accumulated drift is within ``vbound``.
  * **vector-clock gossip**: every response carries the shard's per-worker
    clock vectors, merged into the client's mirror policy; every request
    carries the client's, merged into the shard.  Commit and read-frontier
    events are additionally broadcast to every shard, which is what makes
    clock-gated policies (BSP barriers, SSP slack) exact across shards.
  * **shard-death survival**: every RPC runs under
    :func:`repro.runtime.fault.retry_with_backoff`; connection resets
    reconnect with exponential backoff and resend (shards deduplicate by
    op key, so retries are exactly-once), and each retry is reported into
    the client's Telemetry so it shows up in the run's staleness summary.
"""
from __future__ import annotations

import dataclasses
import socket

import numpy as np

from ...runtime.fault import Backoff, retry_with_backoff
from ..db import WaitTimeout
from ..policies import make_policy
from ..telemetry import Telemetry
from . import protocol as P


@dataclasses.dataclass
class CacheEntry:
    value: np.ndarray
    version: int
    cum: float = 0.0        # shard's cumulative-change ledger at fetch time


class ClientParameterDB:
    """One worker's window onto the sharded ParameterDB."""

    def __init__(self, worker: int, addrs: list[tuple[str, int]],
                 n_workers: int, n_chunks: int,
                 policy: str = "dc", delta: float | list = 0,
                 vbound: float | None = None,
                 timeout: float = 60.0,
                 backoff: Backoff | None = None):
        self.worker = worker
        self.addrs = list(addrs)
        self.p, self.m = n_workers, n_chunks
        self.n_shards = len(addrs)
        # mirror policy: local clock vector + cache-admissibility bounds
        # (admission itself is decided authoritatively at the shards)
        self.policy = make_policy(policy, n_workers, delta,
                                  n_chunks=n_chunks, vbound=vbound)
        self.timeout = timeout
        self.backoff = backoff or Backoff()
        self.telemetry = Telemetry()            # rpc retries -> retried_steps
        self.cache: dict[int, CacheEntry] = {}
        self.stats = {"cache_hits": 0, "cache_misses": 0,
                      "cache_validated": 0, "bytes_saved": 0}
        self.lamport = 0
        self._socks: dict[int, socket.socket] = {}
        self._read_sets: dict[int, set[int]] = {}

    # -- connection management ----------------------------------------------
    def _sock(self, shard: int) -> socket.socket:
        sock = self._socks.get(shard)
        if sock is None:
            sock = P.connect(self.addrs[shard], timeout=self.timeout + 10.0)
            self._socks[shard] = sock
        return sock

    def _drop(self, shard: int) -> None:
        sock = self._socks.pop(shard, None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def close(self) -> None:
        for s in list(self._socks):
            self._drop(s)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- the RPC core --------------------------------------------------------
    def _rpc(self, shard: int, header: dict,
             payload: bytes = b"") -> tuple[dict, bytes]:
        def attempt() -> tuple[dict, bytes]:
            self.lamport += 1
            header["ts"] = self.lamport
            header["clocks"] = self.policy.clocks.as_dict()
            sock = self._sock(shard)
            try:
                P.send_msg(sock, header, payload)
                resp, rp = P.recv_msg(sock)
            except TimeoutError:
                # the shard itself answers admission stalls; a silent socket
                # timeout means a hung/unreachable shard — same diagnostic
                # as the threaded backend's condition-variable timeout
                self._drop(shard)
                raise WaitTimeout(
                    header.get("op", "?")[:1], header.get("worker", -1),
                    header.get("chunk", -1), header.get("itr", -1),
                    self.timeout, self.policy, where=f"shard{shard} (rpc)")
            except OSError:
                self._drop(shard)
                raise
            if not resp.get("ok"):
                if resp.get("stall"):
                    raise WaitTimeout(
                        header.get("op", "?")[:1], header.get("worker", -1),
                        header.get("chunk", -1), header.get("itr", -1),
                        self.timeout, self.policy,
                        message=resp.get("error"))
                if resp.get("retryable"):
                    raise ConnectionResetError(resp.get("error", "retryable"))
                raise RuntimeError(f"shard{shard}: {resp.get('error')}")
            clocks = resp.get("clocks")
            if clocks:
                self.policy.clocks.merge(clocks["commit"], clocks["frontier"])
            self.lamport = max(self.lamport, int(resp.get("ts", 0)))
            return resp, rp

        return retry_with_backoff(
            attempt, self.backoff, retry_on=(ConnectionError,),
            telemetry=self.telemetry,
            describe=f"rpc {header.get('op')} -> shard{shard}")

    def _shard(self, chunk: int) -> int:
        return P.shard_of(chunk, self.n_shards)

    def _broadcast(self, op: str, itr: int,
                   exclude: int | None = None) -> None:
        for s in range(self.n_shards):
            if s != exclude:
                self._rpc(s, {"op": op, "worker": self.worker, "itr": itr})

    # -- the ParameterDB interface ------------------------------------------
    def read(self, worker: int, chunk: int, itr: int) -> np.ndarray:
        entry = self.cache.get(chunk)
        if entry is not None and self.policy.cache_admissible(
                chunk, entry.version, itr):
            self.stats["cache_hits"] += 1
            self.stats["bytes_saved"] += entry.value.nbytes
            self._rpc(self._shard(chunk),
                      {"op": "notify_read", "worker": worker, "chunk": chunk,
                       "itr": itr, "version": entry.version})
            value = entry.value
        else:
            req = {"op": "read", "worker": worker, "chunk": chunk, "itr": itr}
            if entry is not None:
                req["cached_version"] = entry.version
                req["cached_cum"] = entry.cum
            resp, rp = self._rpc(self._shard(chunk), req)
            if resp["modified"]:
                value = P.decode_array(resp, rp)
                self.cache[chunk] = CacheEntry(value, resp["version"],
                                               resp.get("cum", 0.0))
                self.stats["cache_misses"] += 1
            else:
                value = entry.value       # validated: current, or in vbound
                self.stats["cache_validated"] += 1
                self.stats["bytes_saved"] += value.nbytes
        self.policy.did_read(worker, chunk, itr)
        self._note_read(worker, chunk, itr)
        return value.copy()

    def _note_read(self, worker: int, chunk: int, itr: int) -> None:
        s = self._read_sets.setdefault(itr, set())
        s.add(chunk)
        if len(s) == self.m:      # full Def-3 read set done at this itr
            del self._read_sets[itr]
            self.policy.observe_frontier(worker, itr)
            self._broadcast("frontier", itr)

    def read_all(self, worker: int, itr: int) -> list[np.ndarray]:
        return [self.read(worker, j, itr) for j in range(self.m)]

    def write(self, worker: int, chunk: int, itr: int,
              value: np.ndarray) -> None:
        value = np.asarray(value)
        meta, payload = P.encode_array(value)
        owner = self._shard(chunk)
        resp, _ = self._rpc(owner, {"op": "write", "worker": worker,
                                    "chunk": chunk, "itr": itr, **meta},
                            payload)
        self.policy.did_write(worker, chunk, itr)
        self.cache[chunk] = CacheEntry(value.copy(), resp["version"],
                                       resp.get("cum", 0.0))
        self._broadcast("commit", itr, exclude=owner)

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        resp, _ = self._rpc(self._shard(chunk),
                            {"op": "can", "kind": "r", "worker": worker,
                             "chunk": chunk, "itr": itr})
        return bool(resp["admissible"])

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        resp, _ = self._rpc(self._shard(chunk),
                            {"op": "can", "kind": "w", "worker": worker,
                             "chunk": chunk, "itr": itr})
        return bool(resp["admissible"])
