"""Worker-side client of the sharded ParameterDB.

:class:`ClientParameterDB` exposes the exact interface of the in-process
backends — ``read / write / can_read / can_write / read_all`` plus
``history``-style telemetry — so the Sec-6 worker loop, the conformance
suite and the benchmarks run unchanged across process boundaries.

What the client adds over a dumb RPC stub:

  * a **versioned local cache**: a read is served locally when the cached
    version is admissible under the policy bound
    (``policy.cache_admissible``, a monotone predicate evaluated against
    the client's lower-bound clock knowledge — provably conservative).
    Cache-served reads still notify the owner shard (``notify_read``) so
    chunk-local admission state, the Op history and staleness telemetry
    stay authoritative at the shard; what a hit saves is the blocking
    admission wait and the value payload.  Inadmissible cached versions
    are *fetched-and-validated*: the shard answers not-modified (no
    payload) when the cached version is still current — or, under the
    value-bounded policy, when its accumulated drift is within ``vbound``.
  * **batched + pipelined RPC** (protocol v2, the default): ``read_all``
    groups the iteration's read set by owner shard and issues one
    ``read_batch`` frame per shard *concurrently* (all sends first, then
    all receives) — cache hits ride the same frame as piggybacked
    ``notify`` entries.  ``write_many`` goes further: the ``write_batch``
    frames are **write-behind** — sent immediately, their responses
    collected (``_settle_writes``) at the start of the next exchange, so
    the write round-trip overlaps the client's compute and each iteration
    blocks on exactly one round-trip (the pipelined read).  The commit
    clock, cache entries and commit broadcast are only published at settle
    time, after the owner shard acknowledged the batch: a commit
    observation that outran its write would let a clock-gated read (BSP /
    SSP) be admitted elsewhere against the not-yet-applied value.  Commit
    and read-frontier broadcasts are **one-way** (``noreply``) messages
    pipelined on the data sockets — one send, zero receives — instead of
    ``m + S`` sequential blocking round-trips per iteration.  Dropping a
    broadcast is safe: its content (a single clock observation) is
    subsumed by the ``clocks`` header every subsequent request carries, so
    gossip self-repairs; the broadcast only buys wake-up latency.
    ``flush`` turns per-connection FIFO into a delivery barrier (one
    ``ping`` proves everything sent before it was processed).
    ``batched=False`` restores the per-chunk v1 path.
  * **vector-clock gossip**: every response carries the shard's per-worker
    clock vectors, merged into the client's mirror policy; every request
    carries the client's, merged into the shard.  Commit and read-frontier
    events are additionally broadcast to every shard that did not already
    observe the event first-hand (the written shard observes the commit in
    ``did_write``), which is what makes clock-gated policies (BSP
    barriers, SSP slack) exact across shards.
  * **shard-death survival**: every synchronous RPC runs under
    :func:`repro.runtime.fault.retry_with_backoff`; connection resets
    reconnect with exponential backoff and resend (shards deduplicate by
    per-sub-op key, so a replayed batch is exactly-once per sub-op), and
    each retry is reported into the client's Telemetry so it shows up in
    the run's staleness summary.  Connection *establishment* runs inside
    the same guarded region as the send/receive: a connect-phase timeout
    against a hung shard surfaces as the standard :class:`WaitTimeout`
    diagnostic, and connect-phase resets retry with backoff.
"""
from __future__ import annotations

import dataclasses
import socket

import numpy as np

from ...runtime.fault import Backoff, retry_with_backoff
from ..db import WaitTimeout
from ..policies import make_policy
from ..telemetry import Telemetry
from . import protocol as P


@dataclasses.dataclass
class CacheEntry:
    value: np.ndarray
    version: int
    cum: float = 0.0        # shard's cumulative-change ledger at fetch time


@dataclasses.dataclass
class _Conn:
    """One shard's data socket + pipelining state: ids of acked
    fire-and-forget messages still awaiting their acknowledgement, and
    whether any one-way (``noreply``) message has been sent since the last
    synchronous exchange (it needs a ping barrier before teardown)."""
    sock: socket.socket
    pending: set[int] = dataclasses.field(default_factory=set)
    unflushed: bool = False


class ClientParameterDB:
    """One worker's window onto the sharded ParameterDB."""

    def __init__(self, worker: int, addrs: list[tuple[str, int]],
                 n_workers: int, n_chunks: int,
                 policy: str = "dc", delta: float | list = 0,
                 vbound: float | None = None,
                 timeout: float = 60.0,
                 backoff: Backoff | None = None,
                 batched: bool = True):
        self.worker = worker
        self.addrs = list(addrs)
        self.p, self.m = n_workers, n_chunks
        self.n_shards = len(addrs)
        # mirror policy: local clock vector + cache-admissibility bounds
        # (admission itself is decided authoritatively at the shards)
        self.policy = make_policy(policy, n_workers, delta,
                                  n_chunks=n_chunks, vbound=vbound)
        self.timeout = timeout
        self.backoff = backoff or Backoff()
        self.batched = batched
        self.telemetry = Telemetry()            # rpc retries -> retried_steps
        self.cache: dict[int, CacheEntry] = {}
        self.stats = {"cache_hits": 0, "cache_misses": 0,
                      "cache_validated": 0, "bytes_saved": 0,
                      "batch_rpcs": 0, "async_posts": 0}
        self.lamport = 0
        self._next_id = 0
        self._conns: dict[int, _Conn] = {}
        self._read_sets: dict[int, set[int]] = {}
        # write-behind: per shard, one deferred write_batch whose response
        # has not been read yet -> (rid, header, payload, writes)
        self._wb_pending: dict[int, tuple[int, dict, bytes, list]] = {}

    # -- connection management ----------------------------------------------
    def _conn(self, shard: int) -> _Conn:
        conn = self._conns.get(shard)
        if conn is None:
            conn = _Conn(P.connect(self.addrs[shard],
                                   timeout=self.timeout + 10.0))
            self._conns[shard] = conn
        return conn

    def _drop(self, shard: int) -> None:
        conn = self._conns.pop(shard, None)
        if conn is not None:
            try:
                conn.sock.close()
            except OSError:
                pass

    def close(self) -> None:
        for s in list(self._conns):
            self.flush(s)
            self._drop(s)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- the RPC core --------------------------------------------------------
    def _send(self, conn: _Conn, header: dict, payload: bytes = b"") -> int:
        """Stamp (id, ts, clocks) onto ``header`` and put one frame on the
        wire.  Returns the request id."""
        self._next_id += 1
        self.lamport += 1
        header["id"] = self._next_id
        header["ts"] = self.lamport
        header["clocks"] = self.policy.clocks.as_dict()
        P.send_msg(conn.sock, header, payload)
        return self._next_id

    def _fold(self, resp: dict) -> None:
        """Merge a response's clock gossip + Lamport stamp (acks included)."""
        clocks = resp.get("clocks")
        if clocks:
            self.policy.clocks.merge(clocks["commit"], clocks["frontier"])
        self.lamport = max(self.lamport, int(resp.get("ts", 0)))

    def _recv_matched(self, conn: _Conn, rid: int) -> tuple[dict, bytes]:
        """Receive until the response with id ``rid`` arrives, draining
        acknowledgements of earlier fire-and-forget messages pipelined on
        the same socket (they may complete in any order relative to each
        other).  Responses for ids this client never issued are a protocol
        violation."""
        while True:
            resp, rp = P.recv_msg(conn.sock)
            got = resp.get("id")
            self._fold(resp)
            if got == rid or got is None:   # None: pre-id (v1) peer
                return resp, rp
            if got in conn.pending:
                # an async broadcast's ack; a non-ok ack needs no replay —
                # the broadcast's clock content piggybacks on every
                # subsequent request header (gossip self-repairs)
                conn.pending.discard(got)
                continue
            raise ConnectionResetError(
                f"protocol error: response id {got} matches no outstanding "
                f"request (expected {rid})")

    def _check(self, resp: dict, header: dict, shard: int) -> None:
        if resp.get("ok"):
            return
        if resp.get("stall"):
            raise WaitTimeout(
                header.get("op", "?")[:1], header.get("worker", -1),
                header.get("chunk", -1), header.get("itr", -1),
                self.timeout, self.policy, message=resp.get("error"))
        if resp.get("retryable"):
            raise ConnectionResetError(resp.get("error", "retryable"))
        raise RuntimeError(f"shard{shard}: {resp.get('error')}")

    def _timeout_error(self, header: dict, shard: int,
                       phase: str) -> WaitTimeout:
        # the shard itself answers admission stalls; a silent socket
        # timeout means a hung/unreachable shard — same diagnostic as the
        # threaded backend's condition-variable timeout
        return WaitTimeout(
            header.get("op", "?")[:1], header.get("worker", -1),
            header.get("chunk", -1), header.get("itr", -1),
            self.timeout, self.policy, where=f"shard{shard} ({phase})")

    def _rpc(self, shard: int, header: dict,
             payload: bytes = b"") -> tuple[dict, bytes]:
        """One synchronous request/response, retried with backoff across
        connection failures (including the connect phase: a hung shard's
        connect timeout is a WaitTimeout, not a raw socket error)."""
        def attempt() -> tuple[dict, bytes]:
            try:
                conn = self._conn(shard)
                rid = self._send(conn, header, payload)
                resp, rp = self._recv_matched(conn, rid)
            except TimeoutError:
                self._drop(shard)
                raise self._timeout_error(header, shard, "rpc")
            except OSError:
                self._drop(shard)
                raise
            self._check(resp, header, shard)
            return resp, rp

        return retry_with_backoff(
            attempt, self.backoff, retry_on=(ConnectionError,),
            telemetry=self.telemetry,
            on_retry=lambda attempt_no: self._drop(shard),
            describe=f"rpc {header.get('op')} -> shard{shard}")

    def _rpc_pipelined(self, requests: dict[int, tuple[dict, bytes]]
                       ) -> dict[int, tuple[dict, bytes]]:
        """Issue one request per shard *concurrently*: all frames go on the
        wire first, then the responses are collected — total latency is the
        slowest shard's, not the sum.  A shard whose send/receive fails
        falls back to the synchronous retry-with-backoff path (sub-op dedup
        at the shard makes the replay exactly-once)."""
        sent: dict[int, int] = {}
        failed: list[int] = []
        out: dict[int, tuple[dict, bytes]] = {}
        fatal: Exception | None = None
        for s in sorted(requests):
            header, payload = requests[s]
            try:
                sent[s] = self._send(self._conn(s), header, payload)
            except (TimeoutError, OSError):
                self._drop(s)
                failed.append(s)
        for s, rid in sent.items():
            header, payload = requests[s]
            try:
                resp, rp = self._recv_matched(self._conns[s], rid)
                self._check(resp, header, s)
            except WaitTimeout as e:
                # a stalled batch is fatal, but keep draining the other
                # shards' responses first so no socket is left mid-stream
                fatal = fatal or e
                continue
            except TimeoutError:
                self._drop(s)
                fatal = fatal or self._timeout_error(header, s, "rpc")
                continue
            except OSError:
                self._drop(s)
                failed.append(s)
                continue
            out[s] = (resp, rp)
        if fatal is not None:
            raise fatal
        for s in failed:
            header, payload = requests[s]
            out[s] = self._rpc(s, header, payload)
        self.stats["batch_rpcs"] += len(requests)
        return out

    def _post(self, shard: int, header: dict) -> None:
        """Fire-and-forget: pipeline a one-way broadcast on the data socket.
        ``noreply`` tells the shard to send no acknowledgement frame at all
        — the message costs one send and zero receives.  Failures are
        swallowed: the message's clock content rides the header of every
        later request, so a lost broadcast costs latency, never safety."""
        header["noreply"] = True
        try:
            conn = self._conn(shard)
            self._send(conn, header)
            conn.unflushed = True
            self.stats["async_posts"] += 1
        except (TimeoutError, OSError):
            self._drop(shard)

    def flush(self, shard: int | None = None) -> None:
        """Settle deferred writes and barrier outstanding one-way
        broadcasts: a synchronous ``ping`` on each dirty socket proves (by
        per-connection FIFO) that the shard processed everything sent
        before it — used before final-state collection; not needed for
        correctness mid-run."""
        self._settle_writes()
        shards = [shard] if shard is not None else list(self._conns)
        for s in shards:
            conn = self._conns.get(s)
            if conn is None or not conn.unflushed:
                continue
            try:
                rid = self._send(conn, {"op": "ping"})
                resp, _ = self._recv_matched(conn, rid)
                conn.unflushed = False
            except (TimeoutError, OSError):
                self._drop(s)

    def _shard(self, chunk: int) -> int:
        return P.shard_of(chunk, self.n_shards)

    # -- write-behind --------------------------------------------------------
    def _apply_write_results(self, resp: dict, writes: list) -> None:
        cums = {int(c): (int(ver), float(cum))
                for c, ver, cum in resp["results"]}
        for c, a, v in writes:
            ver, cum = cums[c]
            self.policy.did_write(self.worker, c, a)
            self.cache[c] = CacheEntry(v.copy(), ver, cum)

    def _settle_writes(self) -> None:
        """Collect the responses of deferred ``write_batch`` frames, then
        perform every observable effect of the write: the local commit-clock
        bump (``did_write``), the cache entries, and the commit broadcast.
        All of it waits for the owner shard's acknowledgement because **a
        commit observation must never outrun the write it describes**: if
        ``commit[w]=itr`` gossiped to other shards while the write frame was
        still in flight, a clock-gated read (BSP/SSP) could be admitted
        elsewhere against the not-yet-applied value.

        Settle runs before any other exchange on the data sockets, so the
        response is normally already buffered (the shard processed the
        write while the client moved on) — the write's round-trip latency
        is overlapped, not skipped.  A connection failure replays the
        stored frame through the synchronous retry path (shard-side dedup
        makes the replay exactly-once per sub-op); a stall surfaces as the
        standard WaitTimeout, one exchange later than the sequential client
        would have seen it."""
        if not self._wb_pending:
            return
        pending, self._wb_pending = self._wb_pending, {}
        fatal: Exception | None = None
        owners, itr_max = set(), 0
        for s, (rid, header, payload, writes) in pending.items():
            conn = self._conns.get(s)
            try:
                if conn is None:
                    raise ConnectionResetError("connection dropped")
                resp, _ = self._recv_matched(conn, rid)
                self._check(resp, header, s)
            except WaitTimeout as e:
                fatal = fatal or e
                continue
            except TimeoutError:
                self._drop(s)
                fatal = fatal or self._timeout_error(header, s, "settle")
                continue
            except (ConnectionError, OSError):
                self._drop(s)
                resp, _ = self._rpc(s, header, payload)
            self._apply_write_results(resp, writes)
            owners.add(s)
            itr_max = max(itr_max, max(a for _, a, _ in writes))
        if fatal is not None:
            raise fatal
        for s in range(self.n_shards):
            if s not in owners:
                self._post(s, {"op": "commit", "worker": self.worker,
                               "itr": itr_max})

    def _broadcast(self, op: str, itr: int,
                   exclude: int | None = None) -> None:
        for s in range(self.n_shards):
            if s == exclude:
                continue
            header = {"op": op, "worker": self.worker, "itr": itr}
            if self.batched:
                self._post(s, header)
            else:
                self._rpc(s, header)

    # -- the ParameterDB interface ------------------------------------------
    def read(self, worker: int, chunk: int, itr: int) -> np.ndarray:
        self._settle_writes()
        entry = self.cache.get(chunk)
        if entry is not None and self.policy.cache_admissible(
                chunk, entry.version, itr):
            self.stats["cache_hits"] += 1
            self.stats["bytes_saved"] += entry.value.nbytes
            self._rpc(self._shard(chunk),
                      {"op": "notify_read", "worker": worker, "chunk": chunk,
                       "itr": itr, "version": entry.version})
            value = entry.value
        else:
            req = {"op": "read", "worker": worker, "chunk": chunk, "itr": itr}
            if entry is not None:
                req["cached_version"] = entry.version
                req["cached_cum"] = entry.cum
            resp, rp = self._rpc(self._shard(chunk), req)
            if resp["modified"]:
                value = P.decode_array(resp, rp)
                self.cache[chunk] = CacheEntry(value, resp["version"],
                                               resp.get("cum", 0.0))
                self.stats["cache_misses"] += 1
            else:
                value = entry.value       # validated: current, or in vbound
                self.stats["cache_validated"] += 1
                self.stats["bytes_saved"] += value.nbytes
        self.policy.did_read(worker, chunk, itr)
        self._note_read(worker, chunk, itr)
        return value.copy()

    def _note_read(self, worker: int, chunk: int, itr: int) -> None:
        s = self._read_sets.setdefault(itr, set())
        s.add(chunk)
        if len(s) == self.m:      # full Def-3 read set done at this itr
            del self._read_sets[itr]
            self.policy.observe_frontier(worker, itr)
            # the shard serving the completing read learns the frontier from
            # the next message's clock header; everyone else is broadcast to
            self._broadcast("frontier", itr, exclude=self._shard(chunk))

    def read_all(self, worker: int, itr: int) -> list[np.ndarray]:
        """The iteration's full Def-3 read set.  Batched mode: group by
        owner shard, one pipelined ``read_batch`` per shard; cache hits
        become piggybacked ``notify`` entries on the same frames."""
        if not self.batched:
            return [self.read(worker, j, itr) for j in range(self.m)]
        self._settle_writes()
        values: dict[int, np.ndarray] = {}
        groups: dict[int, dict] = {}
        for c in range(self.m):
            g = groups.setdefault(self._shard(c), {"ops": [], "notify": []})
            entry = self.cache.get(c)
            if entry is not None and self.policy.cache_admissible(
                    c, entry.version, itr):
                self.stats["cache_hits"] += 1
                self.stats["bytes_saved"] += entry.value.nbytes
                g["notify"].append([c, itr, entry.version])
                values[c] = entry.value
            else:
                op = [c, itr]
                if entry is not None:
                    op += [entry.version, entry.cum]
                g["ops"].append(op)
        requests = {
            s: ({"op": "read_batch", "worker": worker, "itr": itr,
                 "ops": g["ops"], "notify": g["notify"]}, b"")
            for s, g in groups.items()}
        for s, (resp, rp) in self._rpc_pipelined(requests).items():
            got = P.unpack_arrays(resp.get("manifest") or [], rp)
            for c, served, modified, cum in resp["results"]:
                c = int(c)
                if modified:
                    values[c] = got[c]
                    self.cache[c] = CacheEntry(got[c], int(served),
                                               float(cum))
                    self.stats["cache_misses"] += 1
                else:
                    values[c] = self.cache[c].value
                    self.stats["cache_validated"] += 1
                    self.stats["bytes_saved"] += values[c].nbytes
        for c in range(self.m):
            self.policy.did_read(worker, c, itr)
            self._note_read(worker, c, itr)
        return [values[c].copy() for c in range(self.m)]

    def write(self, worker: int, chunk: int, itr: int,
              value: np.ndarray) -> None:
        self.write_many(worker, [(chunk, itr, value)])

    def write_many(self, worker: int,
                   writes: list[tuple[int, int, np.ndarray]]) -> None:
        """Commit several chunk writes — grouped by owner shard into one
        pipelined ``write_batch`` per shard (batched mode) or sequential
        per-chunk ``write`` RPCs (v1 mode).  The commit-clock broadcast
        goes to the shards that received no write (a written shard observes
        the commit first-hand in ``did_write``)."""
        writes = [(int(c), int(a), np.asarray(v)) for c, a, v in writes]
        owners = {self._shard(c) for c, _, _ in writes}
        if self.batched:
            self._settle_writes()      # at most one deferred write per shard
            groups: dict[int, dict] = {}
            for c, a, v in writes:
                g = groups.setdefault(self._shard(c),
                                      {"ops": [], "arr": {}, "writes": []})
                g["ops"].append([c, a])
                g["arr"][c] = v
                g["writes"].append((c, a, v))
            for s, g in groups.items():
                manifest, payload = P.pack_arrays(g["arr"])
                header = {"op": "write_batch", "worker": worker,
                          "ops": g["ops"], "manifest": manifest}
                try:
                    rid = self._send(self._conn(s), header, payload)
                    self._wb_pending[s] = (rid, header, payload, g["writes"])
                except (TimeoutError, OSError):
                    self._drop(s)      # send failed: sync replay w/ backoff
                    resp, _ = self._rpc(s, header, payload)
                    self._apply_write_results(resp, g["writes"])
            # did_write / cache entries / commit broadcast all happen at
            # settle time, once the owner shard has acknowledged the batch
            # (a commit observation must never outrun its write)
            return
        for c, a, v in writes:
            meta, payload = P.encode_array(v)
            resp, _ = self._rpc(self._shard(c),
                                {"op": "write", "worker": worker,
                                 "chunk": c, "itr": a, **meta}, payload)
            self.policy.did_write(worker, c, a)
            self.cache[c] = CacheEntry(v.copy(), resp["version"],
                                       resp.get("cum", 0.0))
        itr = max(a for _, a, _ in writes)
        for s in range(self.n_shards):
            if s not in owners:
                self._rpc(s, {"op": "commit", "worker": self.worker,
                              "itr": itr})

    def can_read(self, worker: int, chunk: int, itr: int) -> bool:
        self._settle_writes()
        resp, _ = self._rpc(self._shard(chunk),
                            {"op": "can", "kind": "r", "worker": worker,
                             "chunk": chunk, "itr": itr})
        return bool(resp["admissible"])

    def can_write(self, worker: int, chunk: int, itr: int) -> bool:
        self._settle_writes()
        resp, _ = self._rpc(self._shard(chunk),
                            {"op": "can", "kind": "w", "worker": worker,
                             "chunk": chunk, "itr": itr})
        return bool(resp["admissible"])
