"""Wire protocol of the sharded parameter server: length-prefixed frames.

Every message (request or response) is one frame:

    u32_be header_len | header (UTF-8 JSON) | u32_be payload_len | payload

The JSON header carries the op type, coordinates, clock vectors and array
metadata; the payload carries raw ``ndarray`` bytes (C-order) when chunk
values travel, else is empty.  Frames whose header or payload length
exceeds ``MAX_FRAME`` are rejected at receive time (``ConnectionError``).

**Protocol v2 — request ids + batching + pipelining.**  Every request may
carry an ``id`` (the data-plane client always does); the response echoes
it, which is what lets a client *pipeline*: several requests can be on the
wire before their responses are read, each receive matched back to its
request by ``id`` (acknowledged pipelined messages drain in whatever order
they complete relative to the synchronous stream).  A request may instead
carry ``noreply: true`` — the shard processes it and sends **no response
frame at all** (used for clock broadcasts, whose loss is repaired by the
``clocks`` gossip on every later header).  Because a shard serves each
connection FIFO, a synchronous exchange (e.g. ``ping``) doubles as a
delivery barrier for every one-way message sent before it.  v1 peers (the
admin control plane) that send no ``id`` keep strict request/response
alternation.

Header fields by op (all requests also carry ``ts`` — the sender's Lamport
clock — and may carry ``clocks``: ``{"commit": [...], "frontier": [...]}``):

  ``read``         worker, chunk, itr, cached_version?, cached_cum?
  ``read_batch``   worker, itr,
                   ops: [[chunk, itr, cached_version?, cached_cum?], ...],
                   notify: [[chunk, itr, version], ...]  (cache-served
                   reads piggybacked on the same frame); the response
                   carries results: [[chunk, version, modified, cum], ...]
                   plus a ``pack_arrays`` manifest + multi-chunk payload
                   holding every modified chunk
  ``notify_read``  worker, chunk, itr, version   (a cache-served read)
  ``write``        worker, chunk, itr + array payload
  ``write_batch``  worker, ops: [[chunk, itr], ...] + manifest + packed
                   multi-chunk payload; response results:
                   [[chunk, version, cum], ...]
  ``commit``       worker, itr    (commit-clock broadcast; one-way)
  ``frontier``     worker, itr    (read-frontier broadcast; one-way)
  ``can``          kind ('r'|'w'), worker, chunk, itr
  ``init``         config + packed chunk arrays
  ``ping`` / ``pull`` / ``shutdown``

Responses: ``{"ok": true, ...}`` or ``{"ok": false, "error": str,
"stall": bool}`` — ``stall`` marks an admission-wait timeout, which the
client re-raises as :class:`repro.pdb.db.WaitTimeout` with the shard's
diagnostic intact.  A batch response is all-or-stall: sub-ops recorded
before the stalled one stay recorded (the shard deduplicates per sub-op,
so a batch replay is exactly-once per sub-op).

Multi-chunk payloads use ``pack_arrays``/``unpack_arrays``: the manifest
rows are ``[chunk_id, dtype, shape, offset, nbytes]`` into one
concatenated byte string, preserving dtype and shape (0-d and empty
arrays included) chunk by chunk.

Chunk placement is by hash: ``shard_of(chunk, S)`` mixes the chunk id with
a Knuth multiplicative hash before reducing mod S, so consecutive chunks
spread across shards (not a contiguous range partition).
"""
from __future__ import annotations

import json
import socket
import struct

import numpy as np

_LEN = struct.Struct("!I")
MAX_FRAME = 1 << 30          # sanity bound: refuse absurd frames

# A multiplicative hash (Knuth's 2^32 / phi) rather than `chunk % S`, so
# chunk->shard placement is scattered and independent of chunk ordering.
_KNUTH = 2654435761


def shard_of(chunk: int, n_shards: int) -> int:
    return ((chunk * _KNUTH) & 0xFFFFFFFF) % n_shards


def owned_chunks(shard: int, n_chunks: int, n_shards: int) -> list[int]:
    return [c for c in range(n_chunks) if shard_of(c, n_shards) == shard]


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        part = sock.recv(n - len(buf))
        if not part:
            raise ConnectionResetError("peer closed mid-frame")
        buf += part
    return bytes(buf)


def send_msg(sock: socket.socket, header: dict, payload: bytes = b"") -> None:
    hb = json.dumps(header, separators=(",", ":")).encode()
    sock.sendall(_LEN.pack(len(hb)) + hb + _LEN.pack(len(payload)) + payload)


def recv_msg(sock: socket.socket) -> tuple[dict, bytes]:
    (hlen,) = _LEN.unpack(_recv_exact(sock, 4))
    if hlen > MAX_FRAME:
        raise ConnectionError(f"oversized header ({hlen} bytes)")
    header = json.loads(_recv_exact(sock, hlen).decode())
    (plen,) = _LEN.unpack(_recv_exact(sock, 4))
    if plen > MAX_FRAME:
        raise ConnectionError(f"oversized payload ({plen} bytes)")
    payload = _recv_exact(sock, plen) if plen else b""
    return header, payload


def encode_array(arr: np.ndarray) -> tuple[dict, bytes]:
    # order="C" (not ascontiguousarray, whose contract is ndim >= 1 and
    # would silently promote 0-d arrays to shape (1,))
    arr = np.asarray(arr, order="C")
    return ({"dtype": arr.dtype.str, "shape": list(arr.shape)},
            arr.tobytes())


def decode_array(meta: dict, payload: bytes) -> np.ndarray:
    return np.frombuffer(payload, dtype=np.dtype(meta["dtype"])).reshape(
        meta["shape"]).copy()


def pack_arrays(arrays: dict[int, np.ndarray]) -> tuple[list, bytes]:
    """Pack several chunk arrays into one payload: returns (manifest, bytes)
    where manifest rows are [chunk_id, dtype, shape, offset, nbytes]."""
    manifest, parts, off = [], [], 0
    for cid in sorted(arrays):
        a = np.asarray(arrays[cid], order="C")
        b = a.tobytes()
        manifest.append([cid, a.dtype.str, list(a.shape), off, len(b)])
        parts.append(b)
        off += len(b)
    return manifest, b"".join(parts)


def unpack_arrays(manifest: list, payload: bytes) -> dict[int, np.ndarray]:
    out = {}
    for cid, dtype, shape, off, nbytes in manifest:
        out[int(cid)] = np.frombuffer(
            payload[off:off + nbytes],
            dtype=np.dtype(dtype)).reshape(shape).copy()
    return out


def connect(addr: tuple[str, int], timeout: float | None) -> socket.socket:
    sock = socket.create_connection(addr, timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return sock
