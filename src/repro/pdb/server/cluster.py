"""Shard-cluster orchestration: spawn, initialize, kill/restart, collect.

:class:`ShardCluster` turns ``N`` shard server processes plus per-worker
:class:`~repro.pdb.server.client.ClientParameterDB` instances into one
logical ParameterDB:

  * ``start()`` spawns the shard processes (``multiprocessing`` *spawn*
    context — no inherited state), learns their ports over a pipe and
    pushes each shard its hash-owned slice of the initial chunks;
  * ``kill_shard`` / ``restart_shard`` are the fault-drill hooks used by
    :class:`repro.runtime.fault.ShardDeathPlan` — a restart rebinds the
    *same* port and (with ``snapshot_dir``) restores the shard's persisted
    state, so clients recover through reconnect-with-backoff alone;
  * ``pull()`` collects every shard's chunk values, Lamport-stamped Op
    history and staleness counters and reassembles the global view
    (``telemetry.merge_timed_histories`` / ``merge_stats``), on which
    ``repro.core.history.is_sequentially_correct`` is the oracle.

:func:`run_distributed_lr` is the Sec-6 workload on this backend — the
process-level analogue of :func:`repro.core.threaded.run_parallel`, used
by the conformance suite and ``benchmarks/pdb_throughput.py``.

CLI::

    python -m repro.pdb.server.cluster --smoke     # 2 shards x 4 workers

runs the conformance smoke CI uses: dc/delta=0 must be bit-identical to
sequential, and the merged history must be sequentially correct.
"""
from __future__ import annotations

import argparse
import dataclasses
import multiprocessing as mp
import os
import threading
import time

import numpy as np

from ...core.history import Op
from ...runtime.fault import Backoff, retry_with_backoff
from ..telemetry import StalenessStats, merge_stats, merge_timed_histories, \
    summarize
from . import protocol as P
from . import shard as shard_mod
from .client import ClientParameterDB


@dataclasses.dataclass
class PullResult:
    """Global state reassembled from every shard."""
    values: dict[int, np.ndarray]          # chunk id -> value
    history: list[Op]                      # merged global Op history
    per_shard: list[list[tuple[int, Op]]]  # Lamport-stamped, per shard
    stats: StalenessStats                  # folded staleness counters
    versions: dict[int, int]
    cums: dict[int, float]

    def theta(self) -> np.ndarray:
        return np.concatenate([self.values[c] for c in sorted(self.values)])

    def summary(self) -> dict:
        return summarize(self.stats)


class ShardCluster:
    """N shard processes + init/teardown + fault drills + state collection."""

    def __init__(self, init_chunks, n_workers: int, n_shards: int = 2,
                 policy: str = "dc", delta=0, vbound: float | None = None,
                 record: bool = True, timeout: float = 60.0,
                 snapshot_dir: str | None = None, batched: bool = True):
        self.init_chunks = [np.array(c, copy=True) for c in init_chunks]
        self.p, self.m = n_workers, len(self.init_chunks)
        self.n_shards = n_shards
        self.policy, self.delta, self.vbound = policy, delta, vbound
        self.record, self.timeout = record, timeout
        self.snapshot_dir = snapshot_dir
        self.batched = batched
        self.procs: list[mp.process.BaseProcess | None] = [None] * n_shards
        self.addrs: list[tuple[str, int]] = [None] * n_shards
        self._ctx = mp.get_context("spawn")
        self._started = False

    # -- lifecycle -----------------------------------------------------------
    def _snapshot_path(self, shard: int) -> str | None:
        if self.snapshot_dir is None:
            return None
        os.makedirs(self.snapshot_dir, exist_ok=True)
        return os.path.join(self.snapshot_dir, f"shard{shard}.pkl")

    def _spawn(self, shard: int, port: int = 0) -> None:
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=shard_mod._spawn_entry,
            args=(child, self._snapshot_path(shard), port), daemon=True)
        proc.start()
        child.close()
        if not parent.poll(30.0):
            proc.kill()
            raise RuntimeError(f"shard {shard} did not report a port")
        bound = parent.recv()
        parent.close()
        self.procs[shard] = proc
        self.addrs[shard] = ("127.0.0.1", bound)

    def _admin_rpc(self, shard: int, header: dict,
                   payload: bytes = b"") -> tuple[dict, bytes]:
        """One-shot control-plane RPC on a fresh connection, retried across
        the shard's restart window."""
        def attempt():
            sock = P.connect(self.addrs[shard], timeout=self.timeout + 10.0)
            try:
                P.send_msg(sock, header, payload)
                resp, rp = P.recv_msg(sock)
            finally:
                sock.close()
            if not resp.get("ok") and resp.get("retryable"):
                raise ConnectionResetError(resp.get("error", "retryable"))
            if not resp.get("ok"):
                raise RuntimeError(f"shard{shard}: {resp.get('error')}")
            return resp, rp

        return retry_with_backoff(attempt, Backoff(),
                                  describe=f"admin {header.get('op')} "
                                           f"-> shard{shard}")

    def _init_shard(self, shard: int) -> None:
        cfg = shard_mod.ShardConfig(
            shard_id=shard, n_shards=self.n_shards, n_workers=self.p,
            n_chunks=self.m, policy=self.policy, delta=self.delta,
            vbound=self.vbound, timeout=self.timeout, record=self.record)
        owned = {c: self.init_chunks[c]
                 for c in P.owned_chunks(shard, self.m, self.n_shards)}
        manifest, payload = P.pack_arrays(owned)
        self._admin_rpc(shard, {"op": "init", "config": cfg.to_header(),
                                "manifest": manifest}, payload)

    def start(self) -> "ShardCluster":
        for s in range(self.n_shards):
            self._spawn(s)
        for s in range(self.n_shards):
            self._init_shard(s)
        self._started = True
        return self

    def __enter__(self) -> "ShardCluster":
        return self.start() if not self._started else self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def shutdown(self) -> None:
        for s, proc in enumerate(self.procs):
            if proc is None or not proc.is_alive():
                continue
            try:
                self._admin_rpc(s, {"op": "shutdown"})
            except Exception:
                pass
            proc.join(timeout=5.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)
        self._started = False

    # -- fault drills (driven by runtime.fault.ShardDeathPlan) ---------------
    def kill_shard(self, shard: int) -> None:
        proc = self.procs[shard]
        if proc is not None and proc.is_alive():
            proc.kill()
            proc.join(timeout=10.0)
        self.procs[shard] = None

    def restart_shard(self, shard: int) -> None:
        """Relaunch a killed shard on its original port.  With a snapshot
        the shard restores exactly where it died; without one it is
        re-initialized from the cluster's initial chunks (progress on that
        shard is lost — fine for drills, fatal for bit-identity)."""
        self._spawn(shard, port=self.addrs[shard][1])
        resp, _ = self._admin_rpc(shard, {"op": "ping"})
        if not resp.get("initialized"):
            self._init_shard(shard)

    # -- data plane ----------------------------------------------------------
    def make_client(self, worker: int,
                    backoff: Backoff | None = None) -> ClientParameterDB:
        return ClientParameterDB(
            worker, list(self.addrs), self.p, self.m, policy=self.policy,
            delta=self.delta, vbound=self.vbound, timeout=self.timeout,
            backoff=backoff, batched=self.batched)

    def pull(self) -> PullResult:
        values: dict[int, np.ndarray] = {}
        per_shard, stats, versions, cums = [], [], {}, {}
        for s in range(self.n_shards):
            resp, payload = self._admin_rpc(s, {"op": "pull"})
            values.update(P.unpack_arrays(resp["manifest"], payload))
            per_shard.append([(int(t), Op(k, int(w), int(c), int(a)))
                              for t, k, w, c, a in resp["history"]])
            stats.append(StalenessStats(**resp["stats"]))
            versions.update({int(c): v for c, v in resp["versions"].items()})
            cums.update({int(c): v for c, v in resp["cums"].items()})
        return PullResult(values=values,
                          history=merge_timed_histories(per_shard),
                          per_shard=per_shard, stats=merge_stats(stats),
                          versions=versions, cums=cums)


# ---------------------------------------------------------------------------
# The Sec-6 workload on the sharded backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistributedRunStats:
    theta: np.ndarray
    wall_time: float
    history: list[Op]
    staleness: dict
    cache: dict                 # summed client cache counters
    retries: int                # rpc retries across all clients


def run_distributed_lr(task, n_workers: int, n_shards: int = 2,
                       policy: str = "dc", delta=0,
                       vbound: float | None = None,
                       record_history: bool = True,
                       timeout: float = 60.0,
                       snapshot_dir: str | None = None,
                       death_plan=None,
                       backoff: Backoff | None = None,
                       batched: bool = True
                       ) -> DistributedRunStats:
    """Train :class:`repro.core.threaded.LRTask` with ``n_workers`` client
    threads against ``n_shards`` shard processes — the process-level twin of
    :func:`repro.core.threaded.run_parallel` (same chunking, same pre-drawn
    sample schedule, so dc/delta=0 stays bit-identical to sequential).

    ``death_plan`` (a :class:`repro.runtime.fault.ShardDeathPlan`) injects a
    shard kill at a chosen iteration, fired by worker 0 — pair it with
    ``snapshot_dir`` so the restarted shard resumes where it died.

    ``batched=True`` (default) routes the hot paths through the protocol-v2
    batched/pipelined RPC layer (one ``read_batch`` per shard per
    iteration, fire-and-forget clock broadcasts); ``batched=False`` keeps
    the per-chunk v1 round-trips."""
    from ...core.threaded import chunk_slices, chunk_update

    d = task.X.shape[1]
    slices = chunk_slices(d, n_workers)
    schedule = task.sample_schedule()
    init = [np.zeros(sl.stop - sl.start) for sl in slices]

    cluster = ShardCluster(init, n_workers, n_shards, policy=policy,
                           delta=delta, vbound=vbound, record=record_history,
                           timeout=timeout, snapshot_dir=snapshot_dir,
                           batched=batched)
    errors: list[BaseException] = []
    clients: list[ClientParameterDB] = []

    def worker(i: int, db: ClientParameterDB) -> None:
        try:
            for itr in range(1, task.n_iters + 1):
                if i == 0 and death_plan is not None:
                    death_plan.maybe_kill(itr, cluster)
                vals = db.read_all(i, itr)
                theta = np.concatenate(vals)
                new = chunk_update(task, theta, slices[i], itr, schedule)
                db.write(i, i, itr, new)
        except BaseException as e:
            errors.append(e)
            raise

    with cluster:
        clients = [cluster.make_client(i, backoff=backoff)
                   for i in range(n_workers)]
        threads = [threading.Thread(target=worker, args=(i, clients[i]),
                                    daemon=True)
                   for i in range(n_workers)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=timeout * task.n_iters)
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        if any(t.is_alive() for t in threads):
            raise RuntimeError("distributed workers did not terminate "
                               "(deadlock?)")
        for c in clients:     # drain in-flight fire-and-forget broadcasts
            c.flush()         # so pull() sees fully-settled shard state
        pulled = cluster.pull()
        cache = {"cache_hits": 0, "cache_misses": 0,
                 "cache_validated": 0, "bytes_saved": 0}
        retries = 0
        for c in clients:
            for k in cache:
                cache[k] += c.stats[k]
            retries += c.telemetry.stats.retried_steps
            c.close()
    # shard stats can't see client-side reconnects; fold them in so one
    # summary describes the run's synchronization *and* fault behavior
    staleness = pulled.summary()
    staleness["retried_steps"] += retries
    return DistributedRunStats(theta=pulled.theta(), wall_time=wall,
                               history=pulled.history,
                               staleness=staleness,
                               cache=cache, retries=retries)


# ---------------------------------------------------------------------------
# CLI / CI smoke
# ---------------------------------------------------------------------------

def smoke(n_shards: int = 2, n_workers: int = 4, n_iters: int = 8,
          verbose: bool = True, modes: tuple[bool, ...] = (False, True)
          ) -> bool:
    """The tier-2 CI check: dc/delta=0 on a live shard cluster must be
    bit-identical to sequential, with a sequentially-correct merged
    history — on the per-chunk v1 RPC path *and* the batched/pipelined v2
    path (``modes`` selects which).  Returns True on success."""
    from ...core.history import is_sequentially_correct
    from ...core.threaded import LRTask, make_synthetic_lr, run_sequential

    X, y = make_synthetic_lr(200, 24, seed=0)
    task = LRTask(X, y, n_iters=n_iters, mode="gd")
    expect = run_sequential(task, n_workers)
    ok = True
    for batched in modes:
        res = run_distributed_lr(task, n_workers, n_shards, policy="dc",
                                 delta=0, batched=batched)
        identical = bool(np.array_equal(res.theta, expect))
        correct = is_sequentially_correct(res.history, n_workers)
        if verbose:
            print(f"shards={n_shards} workers={n_workers} iters={n_iters} "
                  f"policy=dc delta=0 rpc={'batched' if batched else 'per-op'}")
            print(f"  bit-identical to sequential: {identical}")
            print(f"  merged history sequentially correct: {correct} "
                  f"({len(res.history)} ops)")
            print(f"  staleness: {res.staleness}")
            print(f"  cache: {res.cache}  rpc retries: {res.retries}")
        ok = ok and identical and correct
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="distributed ParameterDB cluster driver")
    ap.add_argument("--smoke", action="store_true",
                    help="run the conformance smoke and exit nonzero on "
                         "failure")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--rpc", choices=["both", "batched", "per-op"],
                    default="both",
                    help="which RPC path(s) the smoke exercises")
    args = ap.parse_args(argv)
    if args.smoke:
        modes = {"both": (False, True), "batched": (True,),
                 "per-op": (False,)}[args.rpc]
        ok = smoke(args.shards, args.workers, args.iters, modes=modes)
        print("SMOKE PASS" if ok else "SMOKE FAIL")
        return 0 if ok else 1
    ap.print_help()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
