"""One shard of the distributed ParameterDB: a TCP server process owning a
hash-assigned subset of the chunks.

A shard is the blocking-threaded backend of :mod:`repro.pdb.db` pushed
across a process boundary: each client connection gets a handler thread,
admission blocks on one shared condition variable, and every completed op
is recorded through the same :class:`repro.pdb.telemetry.Telemetry` —
stamped with the shard's Lamport clock so per-shard histories merge into
one global history (``telemetry.merge_timed_histories``).

Chunk-local policy state (bit vectors, versions, last-read arrays) lives
here authoritatively; cross-shard admission state arrives as per-worker
clock broadcasts (``commit`` / ``frontier`` messages) that the policy
merges via ``observe_commit`` / ``observe_frontier``.  All admission
predicates are monotone in that state, so a shard can never admit an op
the global truth would reject — it can only wait longer.

Retries are safe: every state-mutating message is keyed by
``(kind, worker, chunk, itr)`` and deduplicated, so a client that resends
after a connection reset (shard death drill, ``runtime.fault.Backoff``)
gets at-least-once delivery with exactly-once recording.  With
``--snapshot`` the shard persists its state (chunks, policy, dedup set,
telemetry, Lamport clock) after each mutation and restores it on boot —
a killed-and-restarted shard resumes where it died.

Run standalone:  ``python -m repro.pdb.server.shard --port 7070``
(then initialize it with an ``init`` message — see ``cluster.py``).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import pickle
import socketserver
import threading

import numpy as np

from ..db import stall_diagnostic
from ..policies import make_policy
from ..telemetry import Telemetry
from . import protocol as P


@dataclasses.dataclass
class ShardConfig:
    shard_id: int
    n_shards: int
    n_workers: int
    n_chunks: int
    policy: str = "dc"
    delta: float | list = 0
    vbound: float | None = None
    timeout: float = 60.0
    record: bool = True
    snapshot_path: str | None = None

    def to_header(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("snapshot_path")
        return d


class ShardState:
    """Storage + policy + telemetry for the chunks this shard owns."""

    def __init__(self, cfg: ShardConfig, chunks: dict[int, np.ndarray]):
        self.cfg = cfg
        self.chunks = {int(c): np.array(v, copy=True)
                       for c, v in chunks.items()}
        self.policy = make_policy(cfg.policy, cfg.n_workers, cfg.delta,
                                  n_chunks=cfg.n_chunks, vbound=cfg.vbound)
        self.telemetry = Telemetry(record_history=cfg.record)
        self.version = {c: 0 for c in self.chunks}
        self.cum_change = {c: 0.0 for c in self.chunks}   # vap ledger (L-inf)
        self.seen: set[tuple] = set()
        self.lamport = 0
        self.cond = threading.Condition()

    # -- persistence (shard-death survival) ---------------------------------
    def snapshot(self) -> None:
        """Atomically persist state; called under the condition lock after
        every mutation when a snapshot path is configured."""
        path = self.cfg.snapshot_path
        if not path:
            return
        blob = pickle.dumps({
            "cfg": self.cfg, "chunks": self.chunks, "policy": self.policy,
            "version": self.version, "cum_change": self.cum_change,
            "seen": self.seen, "lamport": self.lamport,
            "telemetry": self.telemetry})
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)

    @classmethod
    def restore(cls, path: str) -> "ShardState":
        with open(path, "rb") as f:
            d = pickle.load(f)
        self = cls.__new__(cls)
        self.cfg = d["cfg"]
        self.chunks, self.policy = d["chunks"], d["policy"]
        self.version, self.cum_change = d["version"], d["cum_change"]
        self.seen, self.lamport = d["seen"], d["lamport"]
        self.telemetry = d["telemetry"]
        self.cond = threading.Condition()
        return self

    # -- helpers (call under self.cond) -------------------------------------
    def _tick(self, ts) -> int:
        self.lamport = max(self.lamport, int(ts or 0)) + 1
        return self.lamport

    def _merge_clocks(self, h: dict) -> None:
        clocks = h.get("clocks")
        if clocks:
            self.policy.clocks.merge(clocks["commit"], clocks["frontier"])
            # merged clocks can satisfy a blocked admission predicate (BSP
            # frontier, SSP slack) even when this message records no op —
            # wake waiters so piggybacked gossip alone makes progress
            self.cond.notify_all()

    def _base_resp(self, chunk: int | None = None) -> dict:
        resp = {"ok": True, "clocks": self.policy.clocks.as_dict(),
                "ts": self.lamport}
        if chunk is not None:
            resp["cum"] = self.cum_change[chunk]
        return resp

    def _stall(self, kind: str, w: int, c: int, a: int) -> tuple[dict, bytes]:
        return ({"ok": False, "stall": True,
                 "error": stall_diagnostic(
                     kind, w, c, a, self.cfg.timeout, self.policy,
                     where=f"shard{self.cfg.shard_id}")}, b"")

    # -- op bodies (call under self.cond) ------------------------------------
    def _admit(self, kind: str, w: int, c: int, a: int) -> bool:
        """Block until the op is admissible (or already recorded — a crash
        retry).  The Lamport stamp of the op is taken *after* this returns:
        an op that waited must be stamped later than the op that admitted
        it, or the merged global history misorders them."""
        key = (kind, w, c, a)
        pred = (self.policy.can_read if kind == "r" else self.policy.can_write)
        return self.cond.wait_for(
            lambda: key in self.seen or pred(w, c, a),
            timeout=self.cfg.timeout)

    def _record_notify(self, w: int, c: int, a: int, ver) -> bool:
        """Record a client-cache-served read (bits, last-read arrays,
        history, staleness at the *observed* version).  Returns True if the
        op was new (False: duplicate delivery)."""
        key = ("r", w, c, a)
        if key in self.seen:
            return False
        self.policy.did_read(w, c, a)
        self.telemetry.on_read(w, c, a, version=ver, lamport=self._tick(None))
        self.seen.add(key)
        self.cond.notify_all()
        return True

    def _serve_read(self, w: int, c: int, a: int, cached_ver,
                    cached_cum) -> tuple[int, bool]:
        """Admitted-read body: conditional serving + recording.  Returns
        (served_version, modified); ``modified=False`` means the client's
        cached copy is still valid (current, or within the value bound) and
        no payload travels."""
        key = ("r", w, c, a)
        ver, cum = self.version[c], self.cum_change[c]
        if key in self.seen:              # crash retry: serve, don't re-record
            return ver, True
        vb = self.cfg.vbound
        if cached_ver is not None and cached_ver == ver:
            served, modified = ver, False             # cache validated
        elif (cached_ver is not None and vb is not None
              and cached_cum is not None and cum - cached_cum <= vb):
            served, modified = cached_ver, False      # within value bound
        else:
            served, modified = ver, True
        self.policy.did_read(w, c, a)
        self.telemetry.on_read(w, c, a, version=served,
                               lamport=self._tick(None))
        self.seen.add(key)
        self.cond.notify_all()
        return served, modified

    def _apply_write(self, w: int, c: int, a: int, arr: np.ndarray) -> None:
        """Admitted-write body: value + drift ledger + recording (idempotent
        under duplicate delivery)."""
        key = ("w", w, c, a)
        if key in self.seen:
            return
        old = self.chunks[c]
        if old.shape == arr.shape:
            diff = np.abs(arr - old)
            self.cum_change[c] += float(diff.max()) if diff.size else 0.0
        self.chunks[c] = arr
        self.version[c] = max(self.version[c], a)
        self.policy.did_write(w, c, a)
        self.telemetry.on_write(w, c, a, lamport=self._tick(None))
        self.seen.add(key)
        self.cond.notify_all()

    # -- message handlers ----------------------------------------------------
    def read(self, h: dict) -> tuple[dict, bytes]:
        w, c, a = h["worker"], h["chunk"], h["itr"]
        with self.cond:
            self._merge_clocks(h)
            self._tick(h.get("ts"))       # receipt event (sender causality)
            if not self._admit("r", w, c, a):
                return self._stall("r", w, c, a)
            served, modified = self._serve_read(
                w, c, a, h.get("cached_version"), h.get("cached_cum"))
            self.snapshot()
            resp = self._base_resp(c)
            resp.update(version=served, modified=modified)
            if modified:
                meta, payload = P.encode_array(self.chunks[c])
                resp.update(meta)
                return resp, payload
            return resp, b""

    def notify_read(self, h: dict) -> tuple[dict, bytes]:
        """A read the client served from its local cache."""
        w, c, a = h["worker"], h["chunk"], h["itr"]
        with self.cond:
            self._merge_clocks(h)
            self._tick(h.get("ts"))
            if self._record_notify(w, c, a, h.get("version")):
                self.snapshot()
            return self._base_resp(c), b""

    def write(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        w, c, a = h["worker"], h["chunk"], h["itr"]
        with self.cond:
            self._merge_clocks(h)
            self._tick(h.get("ts"))
            if not self._admit("w", w, c, a):
                return self._stall("w", w, c, a)
            self._apply_write(w, c, a, P.decode_array(h, payload))
            self.snapshot()
            resp = self._base_resp(c)
            resp["version"] = self.version[c]
            return resp, b""

    def read_batch(self, h: dict) -> tuple[dict, bytes]:
        """Protocol-v2 multi-chunk read: one frame carries every read this
        worker needs from this shard at this iteration, plus piggybacked
        ``notify`` entries for the reads its cache already served.

        Sub-ops are admitted in order under a single condition-lock pass
        (``wait_for`` releases the lock while blocked, so other handler
        threads make progress — the interleaving is exactly the sequential
        per-chunk client's).  Each sub-op gets its own post-admission
        Lamport stamp; ``snapshot()`` runs once per batch.  A stalled
        sub-op fails the whole batch (already-recorded sub-ops are kept:
        the client's retry is deduplicated per sub-op)."""
        w = h["worker"]
        with self.cond:
            self._merge_clocks(h)
            self._tick(h.get("ts"))
            recorded = False
            for c, a, ver in h.get("notify") or []:
                recorded |= self._record_notify(w, int(c), int(a), ver)
            results, send = [], {}
            for op in h.get("ops") or []:
                c, a = int(op[0]), int(op[1])
                cached_ver = op[2] if len(op) > 2 else None
                cached_cum = op[3] if len(op) > 3 else None
                if not self._admit("r", w, c, a):
                    if recorded:
                        self.snapshot()
                    return self._stall("r", w, c, a)
                served, modified = self._serve_read(w, c, a, cached_ver,
                                                    cached_cum)
                recorded = True
                if modified:
                    send[c] = self.chunks[c]
                results.append([c, served, int(modified), self.cum_change[c]])
            if recorded:
                self.snapshot()
            resp = self._base_resp()
            manifest, payload = P.pack_arrays(send)
            resp.update(results=results, manifest=manifest)
            return resp, payload

    def write_batch(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        """Protocol-v2 multi-chunk write: ``ops`` rows are ``[chunk, itr]``
        with the values packed into one payload via the ``pack_arrays``
        manifest.  Same single-lock-pass admission, per-sub-op Lamport
        stamps and once-per-batch snapshot as :meth:`read_batch`."""
        w = h["worker"]
        arrays = P.unpack_arrays(h.get("manifest") or [], payload)
        with self.cond:
            self._merge_clocks(h)
            self._tick(h.get("ts"))
            results, recorded = [], False
            for c, a in h.get("ops") or []:
                c, a = int(c), int(a)
                if not self._admit("w", w, c, a):
                    if recorded:
                        self.snapshot()
                    return self._stall("w", w, c, a)
                self._apply_write(w, c, a, arrays[c])
                recorded = True
                results.append([c, self.version[c], self.cum_change[c]])
            if recorded:
                self.snapshot()
            resp = self._base_resp()
            resp["results"] = results
            return resp, b""

    def observe(self, h: dict) -> tuple[dict, bytes]:
        """commit / frontier clock broadcasts."""
        with self.cond:
            self._merge_clocks(h)
            self._tick(h.get("ts"))
            if h["op"] == "commit":
                self.policy.observe_commit(h["worker"], h["itr"])
            else:
                self.policy.observe_frontier(h["worker"], h["itr"])
            self.snapshot()
            self.cond.notify_all()
            return self._base_resp(), b""

    def can(self, h: dict) -> tuple[dict, bytes]:
        w, c, a = h["worker"], h["chunk"], h["itr"]
        with self.cond:
            # merge + tick like every other handler: clock gossip rides
            # ``can`` requests too, and the response must carry a fresh ts
            self._merge_clocks(h)
            self._tick(h.get("ts"))
            pred = (self.policy.can_read if h["kind"] == "r"
                    else self.policy.can_write)
            resp = self._base_resp()
            resp["admissible"] = bool(pred(w, c, a))
            return resp, b""

    def pull(self, h: dict) -> tuple[dict, bytes]:
        """Final-state collection: values + Lamport-stamped history + stats."""
        with self.cond:
            self._tick(h.get("ts"))
            manifest, payload = P.pack_arrays(self.chunks)
            resp = self._base_resp()
            resp.update(
                manifest=manifest,
                history=[[t, op.kind, op.worker, op.chunk, op.itr]
                         for t, op in self.telemetry.timed_history()],
                stats=dataclasses.asdict(self.telemetry.stats),
                versions={str(c): v for c, v in self.version.items()},
                cums={str(c): v for c, v in self.cum_change.items()})
            return resp, payload


class ShardServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr: tuple[str, int],
                 snapshot_path: str | None = None):
        super().__init__(addr, _Handler)
        self.snapshot_path = snapshot_path
        self.state: ShardState | None = None
        if snapshot_path and os.path.exists(snapshot_path):
            self.state = ShardState.restore(snapshot_path)
            self.state.cfg.snapshot_path = snapshot_path

    def dispatch(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        op = h.get("op")
        if op == "ping":
            return {"ok": True, "initialized": self.state is not None}, b""
        if op == "init":
            if self.state is None:
                cfg = ShardConfig(snapshot_path=self.snapshot_path,
                                  **h["config"])
                chunks = P.unpack_arrays(h["manifest"], payload)
                self.state = ShardState(cfg, chunks)
                self.state.snapshot()
            return {"ok": True, "chunks": sorted(self.state.chunks)}, b""
        if op == "shutdown":
            return {"ok": True}, b""
        if self.state is None:
            # mid-restart window: the client treats this as a transient
            # connection-level failure and retries with backoff
            return {"ok": False, "retryable": True,
                    "error": "shard not initialized"}, b""
        if op == "read":
            return self.state.read(h)
        if op == "read_batch":
            return self.state.read_batch(h)
        if op == "notify_read":
            return self.state.notify_read(h)
        if op == "write":
            return self.state.write(h, payload)
        if op == "write_batch":
            return self.state.write_batch(h, payload)
        if op in ("commit", "frontier"):
            return self.state.observe(h)
        if op == "can":
            return self.state.can(h)
        if op == "pull":
            return self.state.pull(h)
        return {"ok": False, "error": f"unknown op {op!r}"}, b""


class _Handler(socketserver.BaseRequestHandler):
    def setup(self):
        # pipelining puts back-to-back small writes (broadcast ack, then a
        # batch response) on one socket: without NODELAY, Nagle holds the
        # second write for the peer's delayed ACK (~40ms per batch)
        self.request.setsockopt(P.socket.IPPROTO_TCP, P.socket.TCP_NODELAY, 1)

    def handle(self):
        sock = self.request
        while True:
            try:
                h, payload = P.recv_msg(sock)
            except (ConnectionError, OSError):
                return
            try:
                resp, rp = self.server.dispatch(h, payload)
            except Exception as e:     # never kill the connection silently
                resp, rp = {"ok": False,
                            "error": f"{type(e).__name__}: {e}"}, b""
            if h.get("noreply"):       # one-way message (clock broadcast):
                continue               # no response frame at all
            if "id" in h:              # protocol v2: responses echo the
                resp["id"] = h["id"]   # request id (pipelined matching)
            try:
                P.send_msg(sock, resp, rp)
            except (ConnectionError, OSError):
                return
            if h.get("op") == "shutdown":
                threading.Thread(target=self.server.shutdown,
                                 daemon=True).start()
                return


def _spawn_entry(conn, snapshot_path: str | None = None,
                 port: int = 0) -> None:
    """multiprocessing spawn target: bind ``port`` (0 = ephemeral), report
    the bound port through ``conn``, serve until shutdown.  Restarts pass
    the original port so clients can reconnect to a fixed address."""
    server = ShardServer(("127.0.0.1", port), snapshot_path=snapshot_path)
    conn.send(server.server_address[1])
    conn.close()
    server.serve_forever()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="one ParameterDB shard")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--snapshot", default=None,
                    help="state file for crash-restart survival")
    args = ap.parse_args(argv)
    server = ShardServer((args.host, args.port), snapshot_path=args.snapshot)
    print(f"shard listening on {server.server_address[0]}:"
          f"{server.server_address[1]}", flush=True)
    server.serve_forever()


if __name__ == "__main__":
    main()
