"""One shard of the distributed ParameterDB: a TCP server process owning a
hash-assigned subset of the chunks.

A shard is the blocking-threaded backend of :mod:`repro.pdb.db` pushed
across a process boundary: each client connection gets a handler thread,
admission blocks on one shared condition variable, and every completed op
is recorded through the same :class:`repro.pdb.telemetry.Telemetry` —
stamped with the shard's Lamport clock so per-shard histories merge into
one global history (``telemetry.merge_timed_histories``).

Chunk-local policy state (bit vectors, versions, last-read arrays) lives
here authoritatively; cross-shard admission state arrives as per-worker
clock broadcasts (``commit`` / ``frontier`` messages) that the policy
merges via ``observe_commit`` / ``observe_frontier``.  All admission
predicates are monotone in that state, so a shard can never admit an op
the global truth would reject — it can only wait longer.

Retries are safe: every state-mutating message is keyed by
``(kind, worker, chunk, itr)`` and deduplicated, so a client that resends
after a connection reset (shard death drill, ``runtime.fault.Backoff``)
gets at-least-once delivery with exactly-once recording.  With
``--snapshot`` the shard persists its state (chunks, policy, dedup set,
telemetry, Lamport clock) after each mutation and restores it on boot —
a killed-and-restarted shard resumes where it died.

Run standalone:  ``python -m repro.pdb.server.shard --port 7070``
(then initialize it with an ``init`` message — see ``cluster.py``).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import pickle
import socketserver
import threading

import numpy as np

from ..db import stall_diagnostic
from ..policies import make_policy
from ..telemetry import Telemetry
from . import protocol as P


@dataclasses.dataclass
class ShardConfig:
    shard_id: int
    n_shards: int
    n_workers: int
    n_chunks: int
    policy: str = "dc"
    delta: float | list = 0
    vbound: float | None = None
    timeout: float = 60.0
    record: bool = True
    snapshot_path: str | None = None

    def to_header(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("snapshot_path")
        return d


class ShardState:
    """Storage + policy + telemetry for the chunks this shard owns."""

    def __init__(self, cfg: ShardConfig, chunks: dict[int, np.ndarray]):
        self.cfg = cfg
        self.chunks = {int(c): np.array(v, copy=True)
                       for c, v in chunks.items()}
        self.policy = make_policy(cfg.policy, cfg.n_workers, cfg.delta,
                                  n_chunks=cfg.n_chunks, vbound=cfg.vbound)
        self.telemetry = Telemetry(record_history=cfg.record)
        self.version = {c: 0 for c in self.chunks}
        self.cum_change = {c: 0.0 for c in self.chunks}   # vap ledger (L-inf)
        self.seen: set[tuple] = set()
        self.lamport = 0
        self.cond = threading.Condition()

    # -- persistence (shard-death survival) ---------------------------------
    def snapshot(self) -> None:
        """Atomically persist state; called under the condition lock after
        every mutation when a snapshot path is configured."""
        path = self.cfg.snapshot_path
        if not path:
            return
        blob = pickle.dumps({
            "cfg": self.cfg, "chunks": self.chunks, "policy": self.policy,
            "version": self.version, "cum_change": self.cum_change,
            "seen": self.seen, "lamport": self.lamport,
            "telemetry": self.telemetry})
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)

    @classmethod
    def restore(cls, path: str) -> "ShardState":
        with open(path, "rb") as f:
            d = pickle.load(f)
        self = cls.__new__(cls)
        self.cfg = d["cfg"]
        self.chunks, self.policy = d["chunks"], d["policy"]
        self.version, self.cum_change = d["version"], d["cum_change"]
        self.seen, self.lamport = d["seen"], d["lamport"]
        self.telemetry = d["telemetry"]
        self.cond = threading.Condition()
        return self

    # -- helpers (call under self.cond) -------------------------------------
    def _tick(self, ts) -> int:
        self.lamport = max(self.lamport, int(ts or 0)) + 1
        return self.lamport

    def _merge_clocks(self, h: dict) -> None:
        clocks = h.get("clocks")
        if clocks:
            self.policy.clocks.merge(clocks["commit"], clocks["frontier"])

    def _base_resp(self, chunk: int | None = None) -> dict:
        resp = {"ok": True, "clocks": self.policy.clocks.as_dict(),
                "ts": self.lamport}
        if chunk is not None:
            resp["cum"] = self.cum_change[chunk]
        return resp

    def _stall(self, kind: str, w: int, c: int, a: int) -> tuple[dict, bytes]:
        return ({"ok": False, "stall": True,
                 "error": stall_diagnostic(
                     kind, w, c, a, self.cfg.timeout, self.policy,
                     where=f"shard{self.cfg.shard_id}")}, b"")

    # -- message handlers ----------------------------------------------------
    def read(self, h: dict) -> tuple[dict, bytes]:
        w, c, a = h["worker"], h["chunk"], h["itr"]
        key = ("r", w, c, a)
        with self.cond:
            self._merge_clocks(h)
            ts = self._tick(h.get("ts"))
            admissible = self.cond.wait_for(
                lambda: key in self.seen or self.policy.can_read(w, c, a),
                timeout=self.cfg.timeout)
            if not admissible:
                return self._stall("r", w, c, a)
            ver, cum = self.version[c], self.cum_change[c]
            if key in self.seen:          # crash retry: serve, don't re-record
                served, modified = ver, True
            else:
                cached_ver = h.get("cached_version")
                cached_cum = h.get("cached_cum")
                vb = self.cfg.vbound
                if cached_ver is not None and cached_ver == ver:
                    served, modified = ver, False        # cache validated
                elif (cached_ver is not None and vb is not None
                      and cached_cum is not None and cum - cached_cum <= vb):
                    served, modified = cached_ver, False  # within value bound
                else:
                    served, modified = ver, True
                self.policy.did_read(w, c, a)
                self.telemetry.on_read(w, c, a, version=served, lamport=ts)
                self.seen.add(key)
                self.snapshot()
                self.cond.notify_all()
            resp = self._base_resp(c)
            resp.update(version=served, modified=modified)
            if modified:
                meta, payload = P.encode_array(self.chunks[c])
                resp.update(meta)
                return resp, payload
            return resp, b""

    def notify_read(self, h: dict) -> tuple[dict, bytes]:
        """A read the client served from its local cache: record it (bits,
        last-read arrays, history, staleness at the *observed* version)."""
        w, c, a = h["worker"], h["chunk"], h["itr"]
        key = ("r", w, c, a)
        with self.cond:
            self._merge_clocks(h)
            ts = self._tick(h.get("ts"))
            if key not in self.seen:
                self.policy.did_read(w, c, a)
                self.telemetry.on_read(w, c, a, version=h.get("version"),
                                       lamport=ts)
                self.seen.add(key)
                self.snapshot()
                self.cond.notify_all()
            return self._base_resp(c), b""

    def write(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        w, c, a = h["worker"], h["chunk"], h["itr"]
        key = ("w", w, c, a)
        with self.cond:
            self._merge_clocks(h)
            ts = self._tick(h.get("ts"))
            admissible = self.cond.wait_for(
                lambda: key in self.seen or self.policy.can_write(w, c, a),
                timeout=self.cfg.timeout)
            if not admissible:
                return self._stall("w", w, c, a)
            if key not in self.seen:
                arr = P.decode_array(h, payload)
                old = self.chunks[c]
                if old.shape == arr.shape:
                    diff = np.abs(arr - old)
                    self.cum_change[c] += float(diff.max()) if diff.size else 0.0
                self.chunks[c] = arr
                self.version[c] = max(self.version[c], a)
                self.policy.did_write(w, c, a)
                self.telemetry.on_write(w, c, a, lamport=ts)
                self.seen.add(key)
                self.snapshot()
                self.cond.notify_all()
            resp = self._base_resp(c)
            resp["version"] = self.version[c]
            return resp, b""

    def observe(self, h: dict) -> tuple[dict, bytes]:
        """commit / frontier clock broadcasts."""
        with self.cond:
            self._merge_clocks(h)
            self._tick(h.get("ts"))
            if h["op"] == "commit":
                self.policy.observe_commit(h["worker"], h["itr"])
            else:
                self.policy.observe_frontier(h["worker"], h["itr"])
            self.snapshot()
            self.cond.notify_all()
            return self._base_resp(), b""

    def can(self, h: dict) -> tuple[dict, bytes]:
        w, c, a = h["worker"], h["chunk"], h["itr"]
        with self.cond:
            pred = (self.policy.can_read if h["kind"] == "r"
                    else self.policy.can_write)
            resp = self._base_resp()
            resp["admissible"] = bool(pred(w, c, a))
            return resp, b""

    def pull(self, h: dict) -> tuple[dict, bytes]:
        """Final-state collection: values + Lamport-stamped history + stats."""
        with self.cond:
            self._tick(h.get("ts"))
            manifest, payload = P.pack_arrays(self.chunks)
            resp = self._base_resp()
            resp.update(
                manifest=manifest,
                history=[[t, op.kind, op.worker, op.chunk, op.itr]
                         for t, op in self.telemetry.timed_history()],
                stats=dataclasses.asdict(self.telemetry.stats),
                versions={str(c): v for c, v in self.version.items()},
                cums={str(c): v for c, v in self.cum_change.items()})
            return resp, payload


class ShardServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr: tuple[str, int],
                 snapshot_path: str | None = None):
        super().__init__(addr, _Handler)
        self.snapshot_path = snapshot_path
        self.state: ShardState | None = None
        if snapshot_path and os.path.exists(snapshot_path):
            self.state = ShardState.restore(snapshot_path)
            self.state.cfg.snapshot_path = snapshot_path

    def dispatch(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        op = h.get("op")
        if op == "ping":
            return {"ok": True, "initialized": self.state is not None}, b""
        if op == "init":
            if self.state is None:
                cfg = ShardConfig(snapshot_path=self.snapshot_path,
                                  **h["config"])
                chunks = P.unpack_arrays(h["manifest"], payload)
                self.state = ShardState(cfg, chunks)
                self.state.snapshot()
            return {"ok": True, "chunks": sorted(self.state.chunks)}, b""
        if op == "shutdown":
            return {"ok": True}, b""
        if self.state is None:
            # mid-restart window: the client treats this as a transient
            # connection-level failure and retries with backoff
            return {"ok": False, "retryable": True,
                    "error": "shard not initialized"}, b""
        if op == "read":
            return self.state.read(h)
        if op == "notify_read":
            return self.state.notify_read(h)
        if op == "write":
            return self.state.write(h, payload)
        if op in ("commit", "frontier"):
            return self.state.observe(h)
        if op == "can":
            return self.state.can(h)
        if op == "pull":
            return self.state.pull(h)
        return {"ok": False, "error": f"unknown op {op!r}"}, b""


class _Handler(socketserver.BaseRequestHandler):
    def handle(self):
        sock = self.request
        while True:
            try:
                h, payload = P.recv_msg(sock)
            except (ConnectionError, OSError):
                return
            try:
                resp, rp = self.server.dispatch(h, payload)
            except Exception as e:     # never kill the connection silently
                resp, rp = {"ok": False,
                            "error": f"{type(e).__name__}: {e}"}, b""
            try:
                P.send_msg(sock, resp, rp)
            except (ConnectionError, OSError):
                return
            if h.get("op") == "shutdown":
                threading.Thread(target=self.server.shutdown,
                                 daemon=True).start()
                return


def _spawn_entry(conn, snapshot_path: str | None = None,
                 port: int = 0) -> None:
    """multiprocessing spawn target: bind ``port`` (0 = ephemeral), report
    the bound port through ``conn``, serve until shutdown.  Restarts pass
    the original port so clients can reconnect to a fixed address."""
    server = ShardServer(("127.0.0.1", port), snapshot_path=snapshot_path)
    conn.send(server.server_address[1])
    conn.close()
    server.serve_forever()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="one ParameterDB shard")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--snapshot", default=None,
                    help="state file for crash-restart survival")
    args = ap.parse_args(argv)
    server = ShardServer((args.host, args.port), snapshot_path=args.snapshot)
    print(f"shard listening on {server.server_address[0]}:"
          f"{server.server_address[1]}", flush=True)
    server.serve_forever()


if __name__ == "__main__":
    main()
