"""The distributed ParameterDB: one consistency layer, many processes.

This package pushes :mod:`repro.pdb.db` across process boundaries while
keeping its contract intact — same ``read / write / can_read / can_write``
interface, same pluggable :mod:`policies <repro.pdb.policies>`, same
Op-history telemetry, same ``is_sequentially_correct`` oracle:

  * :mod:`protocol` — the wire format (length-prefixed JSON header + raw
    ndarray payload frames) and the Knuth-hash chunk -> shard placement;
  * :mod:`shard` — one server process owning a subset of the chunks:
    authoritative chunk-local policy state, blocking admission on a
    condition variable, Lamport-stamped Op recording, dedup of client
    retries, optional snapshot/restore for crash survival;
  * :mod:`client` — the worker-side :class:`ClientParameterDB`: versioned
    local cache with policy-bounded admissibility, vector-clock gossip
    that makes BSP barriers and SSP slack exact across shards, and
    reconnect-with-backoff so a killed-and-restarted shard is survivable;
  * :mod:`cluster` — spawn/init/kill/restart orchestration plus
    ``pull()``, which reassembles the global chunk values, the merged
    Op history and the folded staleness counters from every shard.

The backend split mirrors the in-process one: where
:class:`~repro.pdb.db.InProcessParameterDB` raises and
:class:`~repro.pdb.db.ThreadedParameterDB` blocks a thread, a shard blocks
the *handler* thread of whichever connection issued the op — admission
semantics are decided by the same policy predicates in all three.
"""
from .client import CacheEntry, ClientParameterDB
from .cluster import (DistributedRunStats, PullResult, ShardCluster,
                      run_distributed_lr, smoke)
from .protocol import owned_chunks, shard_of
from .shard import ShardConfig, ShardServer, ShardState

__all__ = [
    "CacheEntry",
    "ClientParameterDB",
    "DistributedRunStats",
    "PullResult",
    "ShardCluster",
    "ShardConfig",
    "ShardServer",
    "ShardState",
    "owned_chunks",
    "run_distributed_lr",
    "shard_of",
    "smoke",
]
