"""Assigned input-shape cells and their abstract input specs.

Every (arch x shape) cell lowers one of three step kinds:

  train_4k     -> train_step   (seq 4096,   global batch 256)
  prefill_32k  -> prefill_step (seq 32768,  global batch 32)
  decode_32k   -> decode_step  (KV len 32768, global batch 128)
  long_500k    -> decode_step  (KV len 524288, global batch 1;
                                sub-quadratic archs only — DESIGN.md §5)

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) plus the logical-axes tree used by the sharding
engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import cache_axes, init_cache

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.runs_long_context:
        out.append("long_500k")
    return out


def _media_specs(cfg: ModelConfig, batch: int):
    if cfg.frontend == "vision":
        return (SDS((batch, cfg.n_frontend_tokens, cfg.d_frontend),
                    jnp.float32),
                ("batch", None, None))
    return None, None


def input_specs(cfg: ModelConfig, shape_name: str) -> tuple[dict, dict]:
    """Returns (abstract_inputs, logical_axes) for the step function's batch
    arguments."""
    cell = SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        specs = {"tokens": SDS((B, S), jnp.int32),
                 "labels": SDS((B, S), jnp.int32),
                 "mask": SDS((B, S), jnp.float32)}
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
                "mask": ("batch", "seq")}
    elif cell.kind == "prefill":
        specs = {"tokens": SDS((B, S), jnp.int32)}
        axes = {"tokens": ("batch", "seq")}
    else:  # decode: one new token against a KV/recurrent cache of length S
        specs = {"tokens": SDS((B, 1), jnp.int32),
                 "pos": SDS((), jnp.int32)}
        axes = {"tokens": ("batch", None), "pos": ()}
    media, media_axes = _media_specs(cfg, B)
    if media is not None:
        specs["media"] = media
        axes["media"] = media_axes
    return specs, axes


def decode_cache_specs(cfg: ModelConfig, shape_name: str) -> tuple[dict, dict]:
    cell = SHAPES[shape_name]
    cache = init_cache(cfg, cell.global_batch, cell.seq_len, abstract=True)
    return cache, cache_axes(cfg)
