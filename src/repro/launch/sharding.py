"""Logical-axis -> mesh-axis sharding resolution.

A tensor's dims carry logical names (see repro.core.sync_jax); rules map each
name to an ordered list of candidates (a mesh axis or tuple of axes).  The
first candidate whose total size divides the dim and whose mesh axes are not
already used by another dim of the same tensor wins; otherwise the dim is
replicated.  This gives automatic, divisibility-safe fallbacks — e.g. a KV
cache with 8 kv-heads on a 16-way model axis silently falls back to
sequence-parallel (kv_seq -> model) sharding.
"""
from __future__ import annotations

import math
import os
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from ..core.sync_jax import ACTIVATION_RULES

Rules = Mapping[str, Sequence[Any]]


def activation_rules() -> Rules:
    """Activation rules, honoring the REPRO_DP_OVER_MODEL=1 experiment
    toggle: use the `model` axis as additional data parallelism (small
    dense archs whose TP all-reduces dominate — see EXPERIMENTS.md §Perf).
    The parameter database stays sharded over `data` (the paper technique
    is orthogonal to this choice)."""
    if os.environ.get("REPRO_DP_OVER_MODEL") == "1":
        return {**ACTIVATION_RULES,
                "batch": (("pod", "data", "model"), ("data", "model"),
                          ("data",))}
    return ACTIVATION_RULES


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def resolve_spec(logical_axes: Sequence[str | None],
                 shape: Sequence[int], mesh: Mesh, rules: Rules) -> PS:
    spec: list[Any] = []
    used: set[str] = set()
    for dim, name in zip(shape, logical_axes):
        choice = None
        if name is not None:
            for cand in rules.get(name, ()):
                axes = (cand,) if isinstance(cand, str) else tuple(cand)
                if not all(a in mesh.shape for a in axes):
                    continue
                if set(axes) & used:
                    continue
                if dim % _axis_size(mesh, axes) != 0:
                    continue
                choice = axes[0] if len(axes) == 1 else axes
                used.update(axes)
                break
        spec.append(choice)
    return PS(*spec)


def tree_shardings(axes_tree, abstract_tree, mesh: Mesh, rules: Rules):
    """NamedSharding tree for a (axes, ShapeDtypeStruct) tree pair."""
    return jax.tree.map(
        lambda ax, sds: NamedSharding(
            mesh, resolve_spec(ax, sds.shape, mesh, rules)),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def batch_shardings(batch_axes: dict, batch_abstract: dict, mesh: Mesh,
                    rules: Rules | None = None):
    rules = rules or activation_rules()
    return {
        k: NamedSharding(mesh, resolve_spec(batch_axes[k],
                                            batch_abstract[k].shape,
                                            mesh, rules))
        for k in batch_abstract}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PS())


def opt_state_shardings(param_shardings, opt_state_abstract, mesh: Mesh):
    """m/v mirror the parameter shardings; scalars replicate."""
    def pick(path, sds):
        if sds.ndim == 0:
            return replicated(mesh)
        # path like ('m', <param path...>) — look up the param sharding
        key = path[0].key if hasattr(path[0], "key") else str(path[0])
        if key in ("m", "v", "mom", "residual"):
            sub = param_shardings
            for p in path[1:]:
                k = getattr(p, "key", None)
                sub = sub[k] if k is not None else sub[p.idx]
            return sub
        return replicated(mesh)
    return jax.tree_util.tree_map_with_path(pick, opt_state_abstract)
