"""Step-function builders shared by train.py, serve.py and dryrun.py.

``make_train_step`` supports the two synchronization modes (the sharding
difference is applied by the caller via in_shardings — see sync_jax) and
the delta-staleness engine; the step function itself is mode-agnostic pure
dataflow, exactly as Theorem 2 requires: correctness is enforced by the
read/write (all-gather / reduce-scatter) dependency structure, not by the
step code.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..pdb.jax_backend import TrainEngine, make_engine
from ..core.sync_jax import SyncConfig
from ..models.config import ModelConfig
from ..models.transformer import decode_step as model_decode
from ..models.transformer import lm_loss, prefill
from ..optim.optimizers import Optimizer


def make_train_step(cfg: ModelConfig, opt: Optimizer, sync: SyncConfig,
                    act_specs: dict | None = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, batch, cfg, remat=sync.remat,
                                   act_specs=act_specs)
        new_params, new_opt = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        return new_params, new_opt, metrics
    return train_step


def make_lm_grad_fn(cfg: ModelConfig, sync: SyncConfig) -> Callable:
    """grad_fn(params, batch) -> (loss, grads) over the LM loss."""
    def grad_fn(params, batch):
        (loss, _), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, batch, cfg, remat=sync.remat)
        return loss, grads
    return grad_fn


def make_train_engine(cfg: ModelConfig, opt: Optimizer, sync: SyncConfig,
                      params: Any, record_history: bool = False) -> TrainEngine:
    """The unified ParameterDB train engine (both sync and delayed paths)
    used by the training driver; see :mod:`repro.pdb.jax_backend`."""
    return make_engine(params, make_lm_grad_fn(cfg, sync), opt, sync,
                       record_history=record_history)


def make_prefill_step(cfg: ModelConfig, cache_len: int,
                      remat: str = "none",
                      act_specs: dict | None = None) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, batch["tokens"], cfg, cache_len=cache_len,
                       media=batch.get("media"), remat=remat,
                       act_specs=act_specs)
    return prefill_step


def make_decode_step(cfg: ModelConfig,
                     act_specs: dict | None = None) -> Callable:
    def serve_step(params, cache, batch):
        logits, new_cache = model_decode(params, cache, batch["tokens"],
                                         batch["pos"], cfg,
                                         media=batch.get("media"),
                                         act_specs=act_specs)
        return logits, new_cache
    return serve_step
