"""Launcher: production meshes, sharding engine, dry-run, train/serve CLIs."""
