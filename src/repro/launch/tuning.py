"""XLA / platform tuning knobs, applied in one place (SNIPPETS.md §3).

Benchmarks and the training driver call :func:`apply_tuning` first thing,
so every number in BENCH_*.json reflects the same tuned baseline:

  * async collectives + latency-hiding scheduler (GPU; the TPU scheduler
    flag where supported) — overlaps the ParameterDB all-gathers /
    reduce-scatters with compute, which is the whole point of the
    data-centric sharded layout;
  * ``--xla_force_host_platform_device_count=N`` — multi-device SPMD on a
    CPU host (the dry-run / CI environment);
  * optional f64 switch for numerics experiments.

XLA reads these from the environment at backend init, so tuning must run
before the first device computation; flags are appended idempotently and
``REPRO_TUNE=0`` disables everything (untuned A/B baseline).
"""
from __future__ import annotations

import os

GPU_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)
TPU_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true",
)


def apply_tuning(platform: str | None = None,
                 host_device_count: int | None = None,
                 enable_x64: bool = False) -> list[str]:
    """Append tuning flags to XLA_FLAGS; returns the flags added.

    platform: "cpu" | "gpu" | "tpu" | None (autodetect from JAX_PLATFORMS,
    default cpu).  Safe to call repeatedly — already-present flags are
    skipped.  No-op when REPRO_TUNE=0.
    """
    if os.environ.get("REPRO_TUNE", "1") == "0":
        return []
    platform = platform or os.environ.get("JAX_PLATFORMS", "cpu").split(",")[0]

    flags: list[str] = []
    if platform == "gpu":
        flags += GPU_FLAGS
    elif platform == "tpu":
        flags += TPU_FLAGS
    if host_device_count is not None:
        try:
            n_cores = os.cpu_count() or 1
        except Exception:  # pragma: no cover
            n_cores = 1
        n = min(int(host_device_count), max(n_cores, 1))
        flags.append(f"--xla_force_host_platform_device_count={n}")

    current = os.environ.get("XLA_FLAGS", "")
    added = [f for f in flags
             if f.split("=")[0] not in current]
    if added:
        os.environ["XLA_FLAGS"] = (current + " " + " ".join(added)).strip()
    if enable_x64:
        import jax
        jax.config.update("jax_enable_x64", True)
    return added
