"""Serving driver: the continuous-batching engine on an open-loop workload.

Thin CLI wrapper over :class:`repro.serve.ServeEngine`: prompts are
prefilled into paged per-sequence KV/recurrent caches, then decoded
with sequences joining and leaving the batch mid-decode (``--static``
restores the drain-the-batch baseline — same engine, same cache,
admission barrier only).  Arrivals follow a Poisson process at
``--rate`` requests/second.

Prompt-path knobs:

* ``--prefill-chunk C`` — chunked prefill: at most one C-token chunk per
  scheduler tick, interleaved with decode (no drain barrier).
* ``--prefix-cache`` — prompt-prefix caching: requests adopt the KV
  pages of their longest already-computed prefix (implies chunked
  prefill; use ``--shared-prefix`` traffic to see hits).
* ``--temperature`` / ``--top-p`` / ``--sample-seed`` — nucleus
  sampling, deterministically keyed per (request, token index);
  temperature 0 (default) is greedy argmax.

The decode loop dispatches through the kernel layer (repro.kernels.ops):
``--kernel-impl pallas`` runs the fused GQA decode-attention, paged
gather/prefill-attention and grouped MoE kernels on TPU; ``interpret``
emulates them on CPU (slow — parity checks only); the default follows
``REPRO_KERNEL_IMPL`` (XLA reference).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 16 --rate 4 --batch 4 --prefix-cache --shared-prefix
"""
from __future__ import annotations

import argparse
import os

import jax

from ..configs import get_config, get_smoke_config
from ..models import paramlib
from ..models.transformer import model_specs
from ..serve import (ServeConfig, ServeEngine, open_loop_requests,
                     shared_prefix_requests)
from .tuning import apply_tuning


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="sequence slots (B_max)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate, requests/second")
    ap.add_argument("--prompt-len", type=int, default=None,
                    help="fix the prompt length (default: sample 8/16/32)")
    ap.add_argument("--gen", type=int, default=None,
                    help="fix the generation length (default: sample "
                         "4/8/16/48)")
    ap.add_argument("--static", action="store_true",
                    help="drain-the-batch baseline (continuous off)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=None,
                    help="logical KV ring length (default: fits the "
                         "longest prompt+gen, page-aligned)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: tokens per chunk, one chunk "
                         "per tick interleaved with decode (0 = whole-"
                         "prompt prefill at admission)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="prompt-prefix caching: adopt cached KV pages "
                         "for shared prompt prefixes (implies chunked "
                         "prefill at --page-size granularity)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix traffic (hot system prompts + "
                         "unique suffixes) instead of fully random "
                         "prompts")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature)")
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", choices=["ref", "pallas", "interpret"],
                    default=None, help="kernel dispatch (REPRO_KERNEL_IMPL)")
    args = ap.parse_args(argv)
    if args.kernel_impl:
        os.environ["REPRO_KERNEL_IMPL"] = args.kernel_impl
    apply_tuning()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0),
                                dtype=cfg.param_dtype)

    gen_lens = (args.gen,) if args.gen else (4, 8, 16, 48)
    if args.shared_prefix:
        plen = args.prompt_len or 32
        requests = shared_prefix_requests(
            args.requests, args.rate, cfg.vocab_size,
            prefix_len=plen - plen // 4, suffix_lens=(plen // 4,),
            gen_lens=gen_lens, seed=args.seed)
        max_prompt = plen
    else:
        prompt_lens = (args.prompt_len,) if args.prompt_len else (8, 16, 32)
        requests = open_loop_requests(
            args.requests, args.rate, cfg.vocab_size,
            prompt_lens=prompt_lens, gen_lens=gen_lens, seed=args.seed)
        max_prompt = max(prompt_lens)
    page = args.page_size
    need = max_prompt + max(gen_lens)
    cache_len = args.cache_len or -(-need // page) * page

    scfg = ServeConfig(batch_size=args.batch, page_size=page,
                       cache_len=cache_len, continuous=not args.static,
                       prefill_chunk=args.prefill_chunk,
                       prefix_cache=args.prefix_cache,
                       temperature=args.temperature, top_p=args.top_p,
                       sample_seed=args.sample_seed)
    report = ServeEngine(cfg, params, scfg).run(requests)

    print(f"{report.mode}: {report.total_tokens} tokens / "
          f"{report.n_requests} requests in {report.duration:.2f}s "
          f"({report.tokens_per_sec:.1f} tok/s, "
          f"slot utilization {report.utilization:.0%})")
    print(f"latency p50 {report.latency_p50*1e3:.0f}ms "
          f"p99 {report.latency_p99*1e3:.0f}ms over {report.decode_steps} "
          f"decode steps")
    print(f"ttft p50 {report.ttft_p50*1e3:.0f}ms "
          f"p99 {report.ttft_p99*1e3:.0f}ms; "
          f"{report.prefill_chunks} prefill chunks, "
          f"prefix hit rate {report.prefix_hit_rate:.0%}")
    first = report.outputs[min(report.outputs)]
    print("first request:", list(first[:12]))
    return {"report": report, "tok_per_s": report.tokens_per_sec}


if __name__ == "__main__":
    main()
