"""Serving driver: batched prefill + decode loop on CPU (reduced configs).

Demonstrates the inference side of the framework: a batch of prompts is
prefillied into per-sequence KV/recurrent caches, then tokens are decoded
greedily step by step.

The decode loop dispatches through the kernel layer (repro.kernels.ops):
``--kernel-impl pallas`` runs the fused GQA decode-attention and grouped
MoE kernels on TPU; ``interpret`` emulates them on CPU (slow — parity
checks only); the default follows ``REPRO_KERNEL_IMPL`` (XLA reference).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models import paramlib
from ..models.transformer import model_specs, prefill, decode_step
from .tuning import apply_tuning


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", choices=["ref", "pallas", "interpret"],
                    default=None, help="kernel dispatch (REPRO_KERNEL_IMPL)")
    args = ap.parse_args(argv)
    if args.kernel_impl:
        os.environ["REPRO_KERNEL_IMPL"] = args.kernel_impl
    apply_tuning()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0),
                                dtype=cfg.param_dtype)
    key = jax.random.PRNGKey(args.seed)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    media = None
    if cfg.frontend == "vision":
        media = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.float32)

    cache_len = S + args.gen
    t0 = time.time()
    jit_prefill = jax.jit(
        lambda p, t: prefill(p, t, cfg, cache_len=cache_len, media=media))
    logits, cache = jit_prefill(params, prompts)
    t_prefill = time.time() - t0

    jit_decode = jax.jit(
        lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, media=media))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = jit_decode(params, cache, tok,
                                   jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    toks_per_s = B * (args.gen - 1) / max(dt, 1e-9)
    print(f"prefill: {t_prefill*1e3:.0f}ms; decode: {toks_per_s:.1f} tok/s")
    print("generated:", out[:, :12].tolist())
    return {"tokens": out, "tok_per_s": toks_per_s}


if __name__ == "__main__":
    main()
