"""Trip-count-corrected cost analysis.

``compiled.cost_analysis()`` visits every HLO instruction ONCE — a
``jax.lax.scan`` over n layers reports the flops/bytes/collectives of a
single layer (verified empirically: scan n=1/4/16 of the same body all
report identical flops).  Every roofline term would be undercounted by
~n_layers without correction.

Correction: for each block group g we lower the *per-layer body* standalone
(same shardings, same remat structure: fwd for inference paths,
fwd + remat-fwd + bwd via ``jax.grad(checkpoint(body))`` for training — the
exact per-layer work the scanned forward+backward executes) and add
``(n_g - 1) x body_cost`` to the full-module measurement:

    corrected = full_module + sum_g (n_g - 1) * body_g

The correction is validated against a fully-unrolled lowering of the
smallest arch in tests/test_costmodel.py (agreement within a few percent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import activation_rules
from ..models import paramlib
from ..models.config import ModelConfig
from ..models.transformer import (Ctx, _apply_block, _decode_block,
                                  _prefill_block, _remat_wrap, model_specs)
from .sharding import resolve_spec, tree_shardings

SDS = jax.ShapeDtypeStruct


def cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: newer versions
    return the per-device dict directly, older ones a one-element list."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost if cost is not None else {}


def _slot_specs_for_group(cfg: ModelConfig, gi: int):
    """Abstract per-layer (leading scan dim removed) params for group gi,
    with matching shardings."""
    specs = model_specs(cfg)
    group_specs = specs["groups"][f"g{gi}"]
    sliced = jax.tree.map(
        lambda p: paramlib.P(p.shape[1:], p.axes[1:], p.init, p.scale,
                             p.fan_in_dim, p.dtype),
        group_specs, is_leaf=lambda x: isinstance(x, paramlib.P))
    abs_tree = paramlib.abstract_tree(sliced, cfg.param_dtype)
    axes = paramlib.axes_tree(sliced)
    return abs_tree, axes


def _media_abs(cfg: ModelConfig, B: int):
    if cfg.frontend == "vision":
        return SDS((B, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype)
    return None


def group_body_cost(cfg: ModelConfig, gi: int, mesh, rules, kind: str,
                    B: int, S: int, remat: str,
                    parse_collectives) -> dict:
    """Lower one group's per-layer body; returns its cost terms.
    kind: 'train' | 'prefill' | 'decode'."""
    g = cfg.groups[gi]
    abs_params, axes = _slot_specs_for_group(cfg, gi)
    p_shard = tree_shardings(axes, abs_params, mesh, rules)

    from jax.sharding import NamedSharding, PartitionSpec as PS
    import os as _os
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp = dp if len(dp) > 1 else dp[0]
    seq = 1 if kind == "decode" else S
    x_abs = SDS((B, seq, cfg.d_model), cfg.dtype)
    if _os.environ.get("REPRO_SP") == "1" and kind != "decode":
        # sequence parallelism: body I/O seq-sharded over `model`, matching
        # the full-module act constraint
        x_spec = PS(dp, "model", None)
    else:
        x_spec = resolve_spec(("batch", None, None), x_abs.shape, mesh,
                              activation_rules())
    x_shard = NamedSharding(mesh, x_spec)
    media = _media_abs(cfg, B)
    media_shard = NamedSharding(mesh, resolve_spec(
        ("batch", None, None), media.shape, mesh,
        activation_rules())) if media is not None else None

    pos = jnp.arange(seq)[None]

    if kind == "train":
        def inner(x, slot_params, media_v):
            ctx = Ctx(positions=jnp.broadcast_to(pos, (x.shape[0], seq)),
                      media=media_v)
            for si, k in enumerate(g.pattern):
                x, _ = _apply_block(slot_params[f"s{si}"], k, x, cfg, ctx)
            return x

        wrapped = _remat_wrap(inner, remat if remat != "none" else "none")

        # In the scanned execution the forward pass (fwd scan) and the
        # remat-fwd + bwd (bwd scan) live in SEPARATE while loops, so the
        # remat genuinely re-executes.  A standalone value_and_grad lowering
        # would let XLA CSE the primal fwd with the remat fwd and undercount
        # by one forward.  So for remat policies we measure grad-only (DCE
        # drops the unused primal -> remat-fwd + bwd) and add a separate
        # fwd-only lowering.
        def body_grad(x, ct, slot_params, media_v):
            # data-dependent cotangent: prevents XLA constant-folding the
            # backward matmuls (ones-cotangent loses ~half the bwd flops)
            def lossy(xx, pp):
                return jnp.vdot(wrapped(xx, pp, media_v)
                                .astype(jnp.float32), ct)
            if remat == "none":
                return jax.value_and_grad(lossy, argnums=(0, 1))(
                    x, slot_params)
            return jax.grad(lossy, argnums=(0, 1))(x, slot_params)

        body = body_grad
        extra_fwd = (inner if remat != "none" else None)
        ct_abs = SDS((B, seq, cfg.d_model), jnp.float32)
        args = (x_abs, ct_abs, abs_params, media)
        shardings = (x_shard, x_shard, p_shard, media_shard)
    elif kind == "prefill":
        def body(x, slot_params, media_v):
            ctx = Ctx(positions=jnp.broadcast_to(pos, (x.shape[0], seq)),
                      media=media_v)
            out = x
            caches = []
            for si, k in enumerate(g.pattern):
                out, c = _prefill_block(slot_params[f"s{si}"], k, out, cfg,
                                        ctx, S)
                caches.append(c)
            return out, caches
        args = (x_abs, abs_params, media)
        shardings = (x_shard, p_shard, media_shard)
    else:  # decode
        from ..models.transformer import init_cache, cache_axes
        full_cache = init_cache(cfg, B, S, abstract=True)
        full_axes = cache_axes(cfg)
        slot_cache = jax.tree.map(
            lambda sds: SDS(sds.shape[1:], sds.dtype),
            full_cache[f"g{gi}"])
        slot_cache_axes = jax.tree.map(
            lambda ax: ax[1:], full_axes[f"g{gi}"],
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        c_shard = tree_shardings(slot_cache_axes, slot_cache, mesh,
                                 activation_rules())

        def body(x, slot_params, slot_cache_v, media_v):
            ctx = Ctx(positions=jnp.full((x.shape[0], 1), S - 1),
                      media=media_v)
            out = x
            new = {}
            for si, k in enumerate(g.pattern):
                out, nc = _decode_block(slot_params[f"s{si}"], k, out,
                                        slot_cache_v[f"s{si}"], cfg,
                                        jnp.asarray(S - 1, jnp.int32), ctx)
                new[f"s{si}"] = nc
            return out, new
        args = (x_abs, abs_params, slot_cache, media)
        shardings = (x_shard, p_shard, c_shard, media_shard)

    # drop None media arg for non-vision models (jit dislikes None shardings
    # paired with None args only in older versions; keep it simple)
    if media is None:
        def body2(*a):
            return body(*a, None)
        args = args[:-1]
        shardings = shardings[:-1]
    else:
        body2 = body

    with mesh:
        compiled = jax.jit(body2, in_shardings=shardings) \
            .lower(*args).compile()
        cost = cost_dict(compiled)
        coll = parse_collectives(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))

    if kind == "train" and extra_fwd is not None:
        # add the primal forward the fwd scan executes (see comment above)
        def fwd_only(x, slot_params, media_v):
            return extra_fwd(x, slot_params, media_v)
        fargs = (x_abs, abs_params) + ((media,) if media is not None else ())
        fshard = (x_shard, p_shard) + ((media_shard,)
                                       if media is not None else ())
        if media is None:
            def fwd2(x, p):
                return fwd_only(x, p, None)
        else:
            fwd2 = fwd_only
        with mesh:
            fcomp = jax.jit(fwd2, in_shardings=fshard) \
                .lower(*fargs).compile()
            fcost = cost_dict(fcomp)
            fcoll = parse_collectives(fcomp.as_text())
        flops += float(fcost.get("flops", 0.0))
        byts += float(fcost.get("bytes accessed", 0.0))
        for k, v in fcoll.items():
            coll[k] = coll.get(k, 0.0) + v

    return {"flops": flops, "bytes": byts, "collectives": coll, "n": g.n}


def corrected_terms(full_result: dict, body_costs: list[dict]) -> dict:
    """full_module + sum_g (n_g - 1) * body_g for every term."""
    flops = full_result["cost"]["flops_per_device"]
    byts = full_result["cost"]["bytes_per_device"]
    coll = dict(full_result.get("collectives", {}))
    for b in body_costs:
        k = b["n"] - 1
        if k <= 0:
            continue
        flops += k * b["flops"]
        byts += k * b["bytes"]
        for kind, v in b["collectives"].items():
            coll[kind] = coll.get(kind, 0.0) + k * v
    return {"flops_per_device": flops, "bytes_per_device": byts,
            "collectives": coll}
