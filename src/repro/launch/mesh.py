"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the pod axis
is pure data parallelism across pods (gradient all-reduce crosses DCI).

Defined as functions so importing this module never touches jax device
state; the dry-run sets xla_force_host_platform_device_count *before* any
jax initialization (see dryrun.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
DCI_FACTOR = 10.0               # cross-pod links ~10x slower than ICI
