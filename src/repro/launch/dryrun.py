import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).  Do not move them.

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  memory_analysis   — per-device argument/output/temp bytes (proves HBM fit)
  cost_analysis     — per-device HLO flops / bytes accessed
  collectives       — per-op-kind byte totals parsed from the post-SPMD
                      per-device HLO (the roofline collective term)
  roofline terms    — seconds per step for compute / memory / collective

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all                 # full 34-cell sweep
  python -m repro.launch.dryrun --all --mesh multi    # 512-chip pass
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import all_arch_ids, get_config
from ..core.sync_jax import ACTIVATION_RULES, SyncConfig
from ..models import paramlib
from ..models.transformer import model_specs
from ..optim import OptConfig, make_optimizer
from .mesh import (DCI_FACTOR, HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                   make_production_mesh)
from .shapes import SHAPES, applicable_shapes, decode_cache_specs, input_specs
from .sharding import activation_rules, batch_shardings, \
    opt_state_shardings, replicated, tree_shardings
from .steps import make_decode_step, make_prefill_step, make_train_step

# In post-optimization (scheduled) HLO the operands are bare %names, so we
# read each collective's RESULT type(s) from the LHS instead:
#   %all-reduce.5 = f32[16,512]{1,0} all-reduce(%fusion.3), ...
#   %ag = (bf16[2,8]{...}, bf16[2,8]{...}) all-gather-start(...)
# For all-reduce / all-to-all / collective-permute the result size equals the
# operand size; for all-gather the result is the gathered buffer and for
# reduce-scatter the operand is the pre-scatter buffer — we record result
# bytes and convert to wire bytes with the per-op ring factors in
# benchmarks/roofline.py (kept separate so the raw parse stays mechanical).
_COLLECTIVE_LINE_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TYPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result bytes of every collective in the per-device HLO.
    Returns {kind: bytes, kind+'_count': n}."""
    out: dict[str, float] = {}
    for m in _COLLECTIVE_LINE_RE.finditer(hlo_text):
        types, kind = m.group(1), m.group(2)
        total = 0
        for tm in _TYPE_RE.finditer(types):
            dt, dims = tm.group(1), tm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
        out[kind + "_count"] = out.get(kind + "_count", 0) + 1
    return out


def _tree_flat_shardings(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))


def make_act_specs(mesh, sp: bool = False) -> dict:
    """Activation sharding constraints: block I/O sharded over the dp axes;
    logits (and the CE one-hot) additionally vocab-sharded over `model` —
    without this the softmax/one-hot temporaries replicate the vocab dim.

    sp=True additionally shards the SEQUENCE dim of block I/O over `model`
    (Megatron-style sequence parallelism): norms/residuals/embeddings run
    seq-sharded and GSPMD inserts all-gathers only where attention needs the
    full sequence — the fix for archs whose head count cannot shard over the
    model axis (smollm: 15 heads on a 16-way axis)."""
    from jax.sharding import NamedSharding, PartitionSpec as PS
    if os.environ.get("REPRO_DP_OVER_MODEL") == "1":
        dp = (("pod", "data", "model") if "pod" in mesh.shape
              else ("data", "model"))
        return {"act": NamedSharding(mesh, PS(dp, None, None)),
                "logits": NamedSharding(mesh, PS(dp, None, None))}
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp = dp if len(dp) > 1 else dp[0]
    seq_ax = "model" if sp else None
    # logits keep vocab (not seq) on `model` — one axis, one dim
    return {"act": NamedSharding(mesh, PS(dp, seq_ax, None)),
            "logits": NamedSharding(mesh, PS(dp, None, "model"))}


def build_cell(arch: str, shape_name: str, mesh, sync: SyncConfig):
    """Returns (jitted_fn, example_args (abstract), out_shardings_note)."""
    cfg = get_config(arch)
    specs = model_specs(cfg)
    params_abs = paramlib.abstract_tree(specs, cfg.param_dtype)
    axes = paramlib.axes_tree(specs)
    p_shard = tree_shardings(axes, params_abs, mesh, sync.param_rules)
    act_specs = make_act_specs(mesh, sp=os.environ.get("REPRO_SP") == "1")

    cell = SHAPES[shape_name]
    batch_abs, batch_axes = input_specs(cfg, shape_name)
    b_shard = batch_shardings(batch_axes, batch_abs, mesh)

    if cell.kind == "train":
        opt = make_optimizer(OptConfig(name="adamw",
                                       compression=sync.compression))
        step = make_train_step(cfg, opt, sync, act_specs=act_specs)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_shard = opt_state_shardings(p_shard, opt_abs, mesh)
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        args = (params_abs, opt_abs, batch_abs)
    elif cell.kind == "prefill":
        step = make_prefill_step(cfg, cache_len=cell.seq_len,
                                 remat=sync.remat, act_specs=act_specs)
        cache_abs, cache_ax = decode_cache_specs(cfg, shape_name)
        c_shard = tree_shardings(cache_ax, cache_abs, mesh, activation_rules())
        fn = jax.jit(step, in_shardings=(p_shard, b_shard),
                     out_shardings=(None, c_shard))
        args = (params_abs, batch_abs)
    else:  # decode
        step = make_decode_step(cfg, act_specs=act_specs)
        cache_abs, cache_ax = decode_cache_specs(cfg, shape_name)
        c_shard = tree_shardings(cache_ax, cache_abs, mesh, activation_rules())
        fn = jax.jit(step,
                     in_shardings=(p_shard, c_shard, b_shard),
                     out_shardings=(None, c_shard),
                     donate_argnums=(1,))
        args = (params_abs, cache_abs, batch_abs)
    return cfg, fn, args


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N per decoded
    token; prefill = 2*N*D.  MoE counts activated experts only."""
    specs = model_specs(cfg)
    n_total = paramlib.param_count(specs)
    if cfg.is_moe:
        # subtract inactive expert params
        moe_per_layer = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
        n_moe_layers = sum(1 for k in cfg.layer_kinds if k != "xattn")
        inactive = (cfg.n_experts - cfg.top_k) / cfg.n_experts
        n_active = n_total - moe_per_layer * n_moe_layers * inactive
    else:
        n_active = n_total
    cell = SHAPES[shape_name]
    D = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n_active * D
    if cell.kind == "prefill":
        return 2.0 * n_active * D
    return 2.0 * n_active * cell.global_batch      # one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sync: SyncConfig, out_dir: str,
             correct_tripcount: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    cfg, fn, args = build_cell(arch, shape_name, mesh, sync)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        from .costmodel import cost_dict
        cost = cost_dict(compiled)
        hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))

    # XLA counts scan (while) bodies once — add (n-1) x per-layer body cost
    # for every term (see costmodel.py; validated in tests/test_costmodel.py)
    if correct_tripcount:
        from .costmodel import corrected_terms, group_body_cost
        cell = SHAPES[shape_name]
        bodies = []
        for gi in range(len(cfg.groups)):
            bodies.append(group_body_cost(
                cfg, gi, mesh, sync.param_rules, cell.kind,
                cell.global_batch, cell.seq_len, sync.remat,
                lambda txt: {k: v for k, v in
                             parse_collective_bytes(txt).items()
                             if not k.endswith("_count")}))
        corr = corrected_terms(
            {"cost": {"flops_per_device": flops_dev,
                      "bytes_per_device": bytes_dev},
             "collectives": {k: v for k, v in coll.items()
                             if not k.endswith("_count")}},
            bodies)
        flops_dev = corr["flops_per_device"]
        bytes_dev = corr["bytes_per_device"]
        coll = {**coll, **corr["collectives"]}

    coll_bytes = float(sum(v for k, v in coll.items()
                           if not k.endswith("_count")))
    # cost_analysis is on the per-device (post-SPMD) executable
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    link_bw = ICI_BW / (DCI_FACTOR if multi_pod else 1.0)
    collective_s = coll_bytes / link_bw

    mf = model_flops(cfg, shape_name)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "sync_mode": sync.mode, "remat": sync.remat,
        "env": {"dp_over_model":
                os.environ.get("REPRO_DP_OVER_MODEL") == "1",
                "sp": os.environ.get("REPRO_SP") == "1",
                "chunked_ce": os.environ.get("REPRO_CHUNKED_CE") == "1",
                "onehot_cache": os.environ.get("REPRO_ONEHOT_CACHE") == "1"},
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": int(
                getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes_per_device": int(
                getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes_per_device": int(
                getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes_per_device": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev},
        "collectives": coll,
        "collective_bytes_per_device": coll_bytes,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "bottleneck": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
        },
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops_dev if flops_dev else 0.0,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch.replace('.', '_')}__{shape_name}__" \
              f"{'multi' if multi_pod else 'single'}__{sync.mode}" \
              + (f"__{sync.remat}" if sync.remat != "full" else "")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--mode", choices=["datacentric", "bsp"],
                    default="datacentric")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    sync = SyncConfig(mode=args.mode, remat=args.remat)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in all_arch_ids():
            for shp in applicable_shapes(get_config(arch)):
                cells.append((arch, shp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    failures = 0
    for multi in meshes:
        for arch, shp in cells:
            tag = f"{arch}/{shp}/{'multi' if multi else 'single'}"
            out_tag = f"{arch.replace('.', '_')}__{shp}__" \
                      f"{'multi' if multi else 'single'}__{sync.mode}" \
                      + (f"__{sync.remat}" if sync.remat != "full" else "")
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out, out_tag + ".json")):
                print(f"SKIP {tag}")
                continue
            try:
                r = run_cell(arch, shp, multi, sync, args.out)
                rl = r["roofline"]
                print(f"OK   {tag}: compile={r['compile_s']}s "
                      f"peak={r['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                      f"compute={rl['compute_s']*1e3:.2f}ms "
                      f"memory={rl['memory_s']*1e3:.2f}ms "
                      f"coll={rl['collective_s']*1e3:.2f}ms "
                      f"-> {rl['bottleneck']}", flush=True)
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    with open(os.path.join(args.out, out_tag + ".json"),
                              "w") as f:
                        json.dump({"arch": arch, "shape": shp,
                                   "mesh": "multi" if multi else "single",
                                   "status": "fail",
                                   "error": f"{type(e).__name__}: {e}"}, f)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
