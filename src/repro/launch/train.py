"""Training driver: single-host CPU execution of the full stack.

Runs the real training loop (any zoo arch at reduced scale, or the full
config if you have the hardware) with:
  * bsp vs datacentric vs ssp parameter layouts (sync mode),
  * delta-staleness via the unified ParameterDB train engine
    (repro.pdb.jax_backend), with Op/staleness telemetry,
  * atomic checkpointing + auto-resume (--resume),
  * failure injection drills (--fail-at-step), and
  * deterministic data (batch t depends only on (seed, t)).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --smoke \
      --steps 50 --delta 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import latest_step, load_checkpoint, save_checkpoint
from ..configs import get_config, get_smoke_config
from ..core.sync_jax import SyncConfig
from ..data import LMBatchSpec, make_lm_batch
from ..models import paramlib
from ..models.transformer import model_specs
from ..optim import OptConfig, make_optimizer
from ..runtime.fault import FailureInjector, InjectedFailure, RetryPolicy, \
    run_with_recovery
from .steps import make_train_engine
from .tuning import apply_tuning


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    specs = model_specs(cfg)
    params = paramlib.init_tree(specs, jax.random.PRNGKey(args.seed),
                                dtype=cfg.param_dtype)
    opt = make_optimizer(OptConfig(name=args.optimizer, lr=args.lr,
                                   compression=args.compression))
    sync = SyncConfig(mode=args.mode, delta=args.delta,
                      compression=args.compression, remat=args.remat)
    spec = LMBatchSpec(batch=args.batch, seq_len=args.seq,
                       vocab_size=cfg.vocab_size,
                       media_tokens=cfg.n_frontend_tokens,
                       media_dim=cfg.d_frontend, seed=args.seed)
    return cfg, params, opt, sync, spec


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--mode", choices=["datacentric", "bsp", "ssp"],
                    default="datacentric")
    ap.add_argument("--delta", type=int, default=0)
    ap.add_argument("--compression", choices=["none", "int8"], default="none")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash (restart drill)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    apply_tuning()

    cfg, params, opt, sync, spec = build(args)
    start = 0

    # one ParameterDB engine for both paths (sync dict state at delta=0,
    # device ring buffer otherwise) — see repro.pdb.jax_backend
    engine = make_train_engine(cfg, opt, sync, params)
    state = engine.init_state()

    if args.resume and args.ckpt_dir:
        ls = latest_step(args.ckpt_dir)
        if ls is not None:
            state = load_checkpoint(args.ckpt_dir, ls, state)
            state = jax.tree.map(jnp.asarray, state)
            start = ls
            print(f"resumed from step {ls}")

    injector = FailureInjector(
        fail_steps=(args.fail_at_step,) if args.fail_at_step >= 0 else ())
    policy = RetryPolicy()
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = make_lm_batch(spec, step)
        try:
            state, metrics, outcome = run_with_recovery(
                engine.step_fn, state, batch, step, policy, injector,
                is_finite=lambda m: bool(jnp.isfinite(m["loss"]).all()),
                telemetry=engine.telemetry)
        except InjectedFailure:
            print(f"CRASH at step {step} (injected); restart with --resume")
            raise SystemExit(17)
        if outcome != "skipped":    # skipped steps never updated parameters
            engine.record_step()
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} [{outcome}] "
                  f"{(time.time()-t0):.1f}s", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, state)
    tele = engine.telemetry.summary()
    if not losses:   # resumed from a checkpoint at/after the last step:
        # don't re-save — it would label step-`start` weights as args.steps
        print(f"nothing to do: resumed at step {start} >= {args.steps}")
        return {"first_loss": None, "final_loss": None, "telemetry": tele}
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) "
          f"[pdb: {tele['reads']}r/{tele['writes']}w "
          f"max_staleness={tele['max_staleness']:.0f} "
          f"retried={tele['retried_steps']} skipped={tele['skipped_steps']}]")
    return {"first_loss": losses[0], "final_loss": losses[-1],
            "telemetry": tele}


if __name__ == "__main__":
    main()
