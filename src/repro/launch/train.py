"""Training driver: single-host CPU execution of the full stack.

Runs the real training loop (any zoo arch at reduced scale, or the full
config if you have the hardware) with:
  * bsp vs datacentric vs ssp parameter layouts (sync mode),
  * delta-staleness via the unified ParameterDB train engine
    (repro.pdb.jax_backend), with Op/staleness telemetry,
  * a multi-process sharded parameter-server backend (--backend server):
    the raveled parameter vector is split into --param-chunks chunks,
    hash-sharded over --shards server processes, and trained by --workers
    client threads under the same consistency policies (Def-3 partitioned
    SGD: each worker reads all chunks, updates its own chunk group),
  * atomic checkpointing + auto-resume (--resume),
  * failure injection drills (--fail-at-step; --kill-shard-at-step for a
    parameter-server shard death + snapshot-restart drill), and
  * deterministic data (batch t depends only on (seed, t)).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --smoke \
      --steps 50 --delta 2
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 8 --backend server --shards 2 --workers 2 --delta 1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import latest_step, load_checkpoint, save_checkpoint
from ..configs import get_config, get_smoke_config
from ..core.sync_jax import SyncConfig
from ..data import LMBatchSpec, make_lm_batch
from ..models import paramlib
from ..models.transformer import model_specs
from ..optim import OptConfig, make_optimizer
from ..runtime.fault import FailureInjector, InjectedFailure, RetryPolicy, \
    run_with_recovery
from .steps import make_lm_grad_fn, make_train_engine
from .tuning import apply_tuning


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    specs = model_specs(cfg)
    params = paramlib.init_tree(specs, jax.random.PRNGKey(args.seed),
                                dtype=cfg.param_dtype)
    opt = make_optimizer(OptConfig(name=args.optimizer, lr=args.lr,
                                   compression=args.compression))
    sync = SyncConfig(mode=args.mode, delta=args.delta,
                      compression=args.compression, remat=args.remat)
    spec = LMBatchSpec(batch=args.batch, seq_len=args.seq,
                       vocab_size=cfg.vocab_size,
                       media_tokens=cfg.n_frontend_tokens,
                       media_dim=cfg.d_frontend, seed=args.seed)
    return cfg, params, opt, sync, spec


def run_server_backend(args) -> dict:
    """Train against the multi-process sharded ParameterDB
    (:mod:`repro.pdb.server`): parameter-server-style SGD on the raveled
    parameter vector.  Worker ``k`` reads every chunk (policy-admitted,
    cache-served when admissible), computes LM grads on its own
    deterministic batch stream, and writes its owned chunk group — the
    Def-3 program with one logical worker owning many chunks."""
    import numpy as np
    from jax.flatten_util import ravel_pytree

    from ..core.history import is_sequentially_correct
    from ..pdb.server import ShardCluster
    from ..runtime.fault import Backoff, ShardDeathPlan

    cfg, params, _opt, sync, spec = build(args)
    grad_fn = jax.jit(make_lm_grad_fn(cfg, sync))
    flat, unravel = ravel_pytree(params)
    theta0 = jax.device_get(flat)
    p = args.workers
    m = args.param_chunks if args.param_chunks > 0 else 2 * p
    bounds = np.linspace(0, theta0.size, m + 1).astype(int)
    chunks = [theta0[a:b].copy() for a, b in zip(bounds[:-1], bounds[1:])]
    owned = {k: [c for c in range(m) if c % p == k] for k in range(p)}
    policy = {"datacentric": "dc", "bsp": "bsp", "ssp": "ssp"}[args.mode]

    plan = None
    snapshot_dir = args.snapshot_dir or None
    if args.kill_shard_at_step >= 0:
        plan = ShardDeathPlan(kill_at_step=args.kill_shard_at_step,
                              shard=args.shards - 1, restart=True)
        if snapshot_dir is None:
            import tempfile
            snapshot_dir = tempfile.mkdtemp(prefix="pdb-shards-")

    cluster = ShardCluster(chunks, p, args.shards, policy=policy,
                           delta=args.delta, record=True,
                           snapshot_dir=snapshot_dir,
                           batched=args.rpc != "per-op")
    losses: list[float] = []
    errors: list[BaseException] = []
    t0 = time.time()

    def worker(k: int, db) -> None:
        try:
            for itr in range(1, args.steps + 1):
                if k == 0 and plan is not None:
                    plan.maybe_kill(itr, cluster)
                theta = np.concatenate(db.read_all(k, itr))
                pk = unravel(jnp.asarray(theta, dtype=flat.dtype))
                batch = make_lm_batch(spec, (itr - 1) * p + k)
                loss, grads = grad_fn(pk, batch)
                g = jax.device_get(ravel_pytree(grads)[0])
                # one write_batch per owner shard for the whole owned group
                # (per-chunk round-trips on the per-op path)
                db.write_many(k, [
                    (c, itr, theta[int(bounds[c]):int(bounds[c + 1])]
                     - args.lr * g[int(bounds[c]):int(bounds[c + 1])])
                    for c in owned[k]])
                if k == 0:
                    losses.append(float(loss))
                    if (itr - 1) % args.log_every == 0 or itr == args.steps:
                        print(f"step {itr - 1:5d} loss {float(loss):.4f} "
                              f"[server] {(time.time() - t0):.1f}s",
                              flush=True)
        except BaseException as e:
            errors.append(e)
            raise

    import threading
    with cluster:
        clients = [cluster.make_client(k, backoff=Backoff(max_retries=12))
                   for k in range(p)]
        threads = [threading.Thread(target=worker, args=(k, clients[k]),
                                    daemon=True) for k in range(p)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        pulled = cluster.pull()
        retries = sum(c.telemetry.stats.retried_steps for c in clients)
        cache_hits = sum(c.stats["cache_hits"] + c.stats["cache_validated"]
                         for c in clients)
        for c in clients:
            c.close()
    tele = pulled.summary()
    tele["retried_steps"] += retries
    seq_ok = is_sequentially_correct(pulled.history, p)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) "
          f"[server: {args.shards} shards x {p} workers, {m} chunks, "
          f"{tele['reads']}r/{tele['writes']}w "
          f"max_staleness={tele['max_staleness']:.0f} "
          f"cache_served={cache_hits} rpc_retries={retries} "
          f"seq_correct={seq_ok}]")
    return {"first_loss": losses[0], "final_loss": losses[-1],
            "telemetry": tele, "sequentially_correct": seq_ok,
            "rpc_retries": retries}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--mode", choices=["datacentric", "bsp", "ssp"],
                    default="datacentric")
    ap.add_argument("--delta", type=int, default=0)
    ap.add_argument("--compression", choices=["none", "int8"], default="none")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash (restart drill)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--backend", choices=["engine", "server"],
                    default="engine",
                    help="engine: in-process ParameterDB train engine; "
                         "server: multi-process sharded parameter server")
    ap.add_argument("--shards", type=int, default=2,
                    help="server backend: number of shard processes")
    ap.add_argument("--workers", type=int, default=2,
                    help="server backend: number of client worker threads")
    ap.add_argument("--param-chunks", type=int, default=0,
                    help="server backend: chunks the raveled parameter "
                         "vector is split into (0 = 2*workers)")
    ap.add_argument("--kill-shard-at-step", type=int, default=-1,
                    help="server backend: kill+restart the last shard at "
                         "this step (shard-death drill)")
    ap.add_argument("--rpc", choices=["batched", "per-op"], default="batched",
                    help="server backend: protocol-v2 batched/pipelined "
                         "RPC (default) or per-chunk v1 round-trips")
    ap.add_argument("--snapshot-dir", default="",
                    help="server backend: shard snapshot directory "
                         "(crash-restart survival)")
    args = ap.parse_args(argv)
    apply_tuning()

    if args.backend == "server":
        return run_server_backend(args)

    cfg, params, opt, sync, spec = build(args)
    start = 0

    # one ParameterDB engine for both paths (sync dict state at delta=0,
    # device ring buffer otherwise) — see repro.pdb.jax_backend
    engine = make_train_engine(cfg, opt, sync, params)
    state = engine.init_state()

    if args.resume and args.ckpt_dir:
        ls = latest_step(args.ckpt_dir)
        if ls is not None:
            state = load_checkpoint(args.ckpt_dir, ls, state)
            state = jax.tree.map(jnp.asarray, state)
            start = ls
            print(f"resumed from step {ls}")

    injector = FailureInjector(
        fail_steps=(args.fail_at_step,) if args.fail_at_step >= 0 else ())
    policy = RetryPolicy()
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = make_lm_batch(spec, step)
        try:
            state, metrics, outcome = run_with_recovery(
                engine.step_fn, state, batch, step, policy, injector,
                is_finite=lambda m: bool(jnp.isfinite(m["loss"]).all()),
                telemetry=engine.telemetry)
        except InjectedFailure:
            print(f"CRASH at step {step} (injected); restart with --resume")
            raise SystemExit(17)
        if outcome != "skipped":    # skipped steps never updated parameters
            engine.record_step()
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} [{outcome}] "
                  f"{(time.time()-t0):.1f}s", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, state)
    tele = engine.telemetry.summary()
    if not losses:   # resumed from a checkpoint at/after the last step:
        # don't re-save — it would label step-`start` weights as args.steps
        print(f"nothing to do: resumed at step {start} >= {args.steps}")
        return {"first_loss": None, "final_loss": None, "telemetry": tele}
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) "
          f"[pdb: {tele['reads']}r/{tele['writes']}w "
          f"max_staleness={tele['max_staleness']:.0f} "
          f"retried={tele['retried_steps']} skipped={tele['skipped_steps']}]")
    return {"first_loss": losses[0], "final_loss": losses[-1],
            "telemetry": tele}


if __name__ == "__main__":
    main()
