"""End-to-end driver: train a ~110M-parameter llama-family model for a few
hundred steps on synthetic data, with checkpointing and restart drills.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    PYTHONPATH=src python examples/train_100m.py --steps 300 --resume
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.sync_jax import SyncConfig
from repro.data import LMBatchSpec, make_lm_batch
from repro.launch.steps import make_train_step
from repro.models import paramlib
from repro.models.config import BlockGroup, ModelConfig
from repro.models.transformer import model_specs
from repro.optim import OptConfig, make_optimizer


def config_100m() -> ModelConfig:
    """~110M params: 12L d768 ff2048 vocab 32k (llama-family)."""
    base = get_config("llama3.2-1b")
    return dataclasses.replace(
        base, name="llama-110m", groups=(BlockGroup(("attn",), 12),),
        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048, head_dim=64,
        vocab_size=32000, max_seq=2048, dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = config_100m()
    specs = model_specs(cfg)
    params = paramlib.init_tree(specs, jax.random.PRNGKey(0))
    print(f"{cfg.name}: {paramlib.param_count(specs)/1e6:.1f}M params")

    opt = make_optimizer(OptConfig(lr=1e-3, weight_decay=0.01))
    step = jax.jit(make_train_step(cfg, opt, SyncConfig()),
                   donate_argnums=(0, 1))
    opt_state = opt.init(params)
    spec = LMBatchSpec(batch=args.batch, seq_len=args.seq,
                       vocab_size=cfg.vocab_size, seed=0)

    start = 0
    if args.resume:
        ls = latest_step(args.ckpt_dir)
        if ls is not None:
            state = load_checkpoint(args.ckpt_dir, ls,
                                    {"p": params, "o": opt_state})
            params = jax.tree.map(jnp.asarray, state["p"])
            opt_state = jax.tree.map(jnp.asarray, state["o"])
            start = ls
            print(f"resumed from step {ls}")

    t0 = time.time()
    for t in range(start, args.steps):
        params, opt_state, m = step(params, opt_state, make_lm_batch(spec, t))
        if t % 10 == 0 or t == args.steps - 1:
            dt = time.time() - t0
            print(f"step {t:4d}  loss {float(m['loss']):.4f}  "
                  f"({dt/max(t-start+1,1):.1f}s/step)", flush=True)
        if (t + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, t + 1,
                            {"p": params, "o": opt_state})
    save_checkpoint(args.ckpt_dir, args.steps, {"p": params, "o": opt_state})
    print("done")


if __name__ == "__main__":
    main()
