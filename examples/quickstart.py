"""Quickstart: build a zoo model, train a few steps, then serve from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.sync_jax import SyncConfig
from repro.data import LMBatchSpec, make_lm_batch
from repro.launch.steps import make_train_step
from repro.models import paramlib
from repro.models.transformer import decode_step, model_specs, prefill
from repro.optim import OptConfig, make_optimizer


def main():
    # 1. pick an architecture (any of the 10 zoo ids; smoke = CPU-sized)
    cfg = get_smoke_config("llama3.2-1b")
    specs = model_specs(cfg)
    params = paramlib.init_tree(specs, jax.random.PRNGKey(0))
    print(f"{cfg.name}: {paramlib.param_count(specs):,} params (reduced)")

    # 2. train a few steps under data-centric synchronization
    opt = make_optimizer(OptConfig(lr=3e-3))
    step = jax.jit(make_train_step(cfg, opt, SyncConfig(mode="datacentric")))
    opt_state = opt.init(params)
    spec = LMBatchSpec(batch=4, seq_len=64, vocab_size=cfg.vocab_size, seed=0)
    for t in range(20):
        params, opt_state, m = step(params, opt_state, make_lm_batch(spec, t))
        if t % 5 == 0:
            print(f"  step {t:3d}  loss {float(m['loss']):.4f}")

    # 3. serve: prefill a prompt, decode a few tokens
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                                cfg.vocab_size)
    logits, cache = prefill(params, prompt, cfg, cache_len=32)
    tok = jnp.argmax(logits, -1)[:, None]
    out = [int(tok[0, 0])]
    for i in range(8):
        logits, cache = decode_step(params, cache, tok,
                                    jnp.asarray(16 + i, jnp.int32), cfg)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(int(tok[0, 0]))
    print("decoded token ids:", out)


if __name__ == "__main__":
    main()
