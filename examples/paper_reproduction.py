"""Reproduce the paper's Sec-6 experiments end to end.

1. LIVE: multi-threaded feature-partitioned linear regression under BSP vs
   data-centric RC/WC — verifies the bit-identical sequential-correctness
   claim on real threads and reports wall-clock.
2. SIMULATED: the Fig-2a/2e scaling curves from the calibrated
   discrete-event model (worker counts beyond what one container exercises).

    PYTHONPATH=src python examples/paper_reproduction.py
"""
import numpy as np

from repro.core import threaded as T
from repro.core.simulator import improvement_pct, trimmed_mean


def live_linear_regression():
    print("== live threaded linear regression (Sec 6 workload) ==")
    X, y = T.make_synthetic_lr(n_examples=500, n_features=96, seed=0)
    for mode in ("gd", "sgd", "minibatch"):
        task = T.LRTask(X, y, n_iters=15, mode=mode, batch_size=32)
        seq = T.run_sequential(task, n_workers=4)
        dc = T.run_parallel(task, 4, policy="dc")
        bsp = T.run_parallel(task, 4, policy="bsp")
        print(f"  {mode:10s} bit-identical: dc={np.array_equal(seq, dc.theta)}"
              f" bsp={np.array_equal(seq, bsp.theta)}"
              f"  wall: dc={dc.wall_time*1e3:6.1f}ms"
              f" bsp={bsp.wall_time*1e3:6.1f}ms"
              f"  final-loss={T.loss(task, dc.theta):.5f}")
    # delta > 0: bounded staleness (Sec 7) — converges, may differ
    task = T.LRTask(X, y, n_iters=30, mode="gd", lr=0.3)
    d2 = T.run_parallel(task, 4, policy="dc", delta=2)
    print(f"  delta=2    loss={T.loss(task, d2.theta):.5f} "
          f"(sequential {T.loss(task, T.run_sequential(task, 4)):.5f})")


def simulated_scaling():
    print("== simulated Fig-2a (GD) and Fig-2e (SGD) improvement % ==")
    print("  workers |    GD   |   SGD")
    for p in (6, 12, 16, 24, 32, 40):
        gd = trimmed_mean([improvement_pct(
            dict(n_workers=p, n_iters=40, compute_mu=8.0, seed=s))
            for s in range(10)])
        sgd = trimmed_mean([improvement_pct(
            dict(n_workers=p, n_iters=40, compute_mu=0.5, seed=s))
            for s in range(10)])
        print(f"  {p:7d} | {gd:6.1f}% | {sgd:6.1f}%")
    print("  (paper: GD 20%->55% rising; SGD 70-75% falling to 40-50%)")


if __name__ == "__main__":
    live_linear_regression()
    simulated_scaling()
