"""Batched serving example: prefill a batch of prompts, decode with one
KV/recurrent cache per sequence — including an attention-free arch where
the state is O(1) in context length.

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b

The decode loop runs through the kernel dispatch layer: pass
``--kernel-impl pallas`` on TPU for the fused decode-attention / grouped
MoE fast path (``interpret`` emulates it on CPU for parity checks).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.tuning import apply_tuning
from repro.models import paramlib
from repro.models.transformer import decode_step, model_specs, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--kernel-impl", choices=["ref", "pallas", "interpret"],
                    default=None, help="kernel dispatch (REPRO_KERNEL_IMPL)")
    args = ap.parse_args()
    if args.kernel_impl:
        os.environ["REPRO_KERNEL_IMPL"] = args.kernel_impl
    apply_tuning()

    cfg = get_smoke_config(args.arch)
    params = paramlib.init_tree(model_specs(cfg), jax.random.PRNGKey(0))
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab_size)
    media = None
    if cfg.frontend == "vision":
        media = jax.random.normal(
            jax.random.PRNGKey(2),
            (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.float32)

    cache_len = S + args.gen
    jit_prefill = jax.jit(lambda p, t: prefill(
        p, t, cfg, cache_len=cache_len, media=media))
    jit_decode = jax.jit(lambda p, c, t, pos: decode_step(
        p, c, t, pos, cfg, media=media))

    t0 = time.time()
    logits, cache = jit_prefill(params, prompts)
    jax.block_until_ready(logits)
    print(f"prefill {B}x{S}: {(time.time()-t0)*1e3:.0f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    seqs = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = jit_decode(params, cache, tok,
                                   jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        seqs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    out = jnp.concatenate(seqs, axis=1)
    print(f"decode: {B*(args.gen-1)/dt:.0f} tok/s "
          f"({dt/(args.gen-1)*1e3:.1f} ms/step)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
