"""Static-batch serving example — the drain-the-batch baseline.

One code path with the continuous-batching driver: this forwards to
``repro.launch.serve`` with ``--static``, i.e. the same engine and paged
cache with admission barriers turned back on (a new wave only starts once
every slot has drained).  Compare against the default continuous mode to
see the slot-utilization gap:

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b

All unrecognized flags pass straight through to the driver (e.g.
``--rate``, ``--batch``, ``--kernel-impl pallas`` on TPU).
"""
import sys

from repro.launch.serve import main


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "rwkv6-1.6b"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    main(argv + ["--static"])
